//! Paged decode-cache arena with refcounted copy-on-write sharing
//! (DESIGN.md §Pages).
//!
//! The monolithic decode path allocates every session's K/V and sorted
//! caches at worst-case capacity (`nb_cap * b * d` per head per side), so
//! serving memory scales with `sessions * max_len` even when most
//! sequences are short. This module is the substrate that turns those
//! owned buffers into *views over a shared arena*:
//!
//! * **[`PagePool`]** — a process-wide arena of fixed-size f32 pages. A
//!   page is `blocks_per_page` Sinkhorn blocks of one head's K or V (the
//!   engine is already block-aligned, so the page is the natural quantum:
//!   the local causal window and the gather both stay inside whole
//!   blocks). Freed pages return to a size-keyed free list and are
//!   recycled, zeroed, on the next allocation.
//! * **[`Page`]** — a refcounted handle (`Arc` under the hood). `Clone`
//!   is the sharing primitive: forking a session's state bumps refcounts
//!   instead of copying floats. [`Page::make_mut`] is the write
//!   primitive: unique pages are written in place, shared pages are
//!   copied first (copy-on-write) so a write can never mutate data
//!   another session still reads — `tests/pages_props.rs` pins this.
//! * **[`PageTable`]** — a session's ordered view of its blocks. Pages
//!   are allocated lazily on first write, so a session at length ℓ
//!   holds `ceil(ceil(ℓ/b) / blocks_per_page)` pages per cached tensor:
//!   resident bytes follow the *actual* length, not the capacity.
//! * **Accounting** — every allocation and free updates the pool's
//!   counters under one mutex; [`PagePool::stats`] exposes
//!   `pages_in_use`/bytes so `memory.rs` and the scheduler admit by what
//!   is actually resident. Dropping the last handle to a page returns
//!   its buffer to the free list exactly once (the `Drop` impl runs once
//!   by `Arc` semantics; `tests/pages_props.rs` churns sessions to pin
//!   the ledger).
//!
//! Sharing soundness leans on the decode path's append-only discipline
//! (DESIGN.md §Decode): K/V blocks are written once, left-to-right, and
//! the frozen SortCut cut cache never changes after it completes — so
//! two sessions opened on a common prompt prefix can share every full
//! page of that prefix and only ever diverge through `make_mut` copies
//! of the pages they write next.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, Weak};

/// Stable panic payload of an injected allocation failure — surfaces to
/// clients as `error=page allocation failed` (DESIGN.md §Faults).
pub const ALLOC_FAIL_MSG: &str = "page allocation failed";

/// Injection seam for allocation faults (DESIGN.md §Faults). A pool built
/// with [`PagePool::with_faults`] consults this once per [`PagePool::alloc`]
/// call; `on_alloc() == true` makes that allocation panic with
/// [`ALLOC_FAIL_MSG`] *before* the ledger is touched, so a caught fault
/// leaves the pool's accounting exactly as it was. The serving stack's
/// `FaultPlan` implements this with a deterministic ordinal schedule.
pub trait AllocFault: Send + Sync {
    /// Count one allocation event; true iff it should fail.
    fn on_alloc(&self) -> bool;
}

/// Lock the pool ledger, tolerating poison: a panic caught by the serving
/// layer's containment must not make every later alloc/free/stats call
/// panic in turn. The ledger is updated in straight-line code with no
/// unwind points between field writes (the fault seam fires before the
/// lock), so a poisoned guard's data is still consistent.
fn lock_inner(m: &Mutex<PoolInner>) -> MutexGuard<'_, PoolInner> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Book-keeping behind the pool mutex: the size-keyed free list plus the
/// in-use/free ledgers the stats report.
#[derive(Default)]
struct PoolInner {
    /// recycled buffers keyed by element count — allocation only ever
    /// reuses an exact-size buffer, so mixed page sizes (K/V pages vs
    /// SortCut cut pages) never alias
    free: BTreeMap<usize, Vec<Box<[f32]>>>,
    pages_in_use: usize,
    elems_in_use: usize,
    elems_free: usize,
    /// fresh buffers ever created (free-list reuse does not count)
    created: usize,
    /// buffers ever returned to the free list (each page exactly once)
    freed: usize,
}

struct PoolShared {
    inner: Mutex<PoolInner>,
    /// optional allocation-fault seam; `None` in production pools
    faults: Option<Arc<dyn AllocFault>>,
}

/// Shared arena of fixed-size f32 pages. Cheap to clone (`Arc` handle);
/// every [`DecodeState`](super::decode::DecodeState) of a paged model
/// holds one so allocation, copy-on-write and free all settle against the
/// same ledger.
#[derive(Clone)]
pub struct PagePool {
    shared: Arc<PoolShared>,
}

/// Snapshot of the pool ledger — the measured side of the §4 paged memory
/// model (`memory.rs` analytic counts are asserted equal in
/// `tests/pages_props.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// pages currently referenced by at least one live handle
    pub pages_in_use: usize,
    /// f32 elements across the in-use pages
    pub elems_in_use: usize,
    /// recycled buffers waiting on the free list
    pub free_pages: usize,
    /// f32 elements across the free list
    pub elems_free: usize,
    /// fresh buffers ever created
    pub created: usize,
    /// buffers ever returned to the free list
    pub freed: usize,
}

impl PoolStats {
    /// Resident bytes actually pinned by live sessions (free-list buffers
    /// are recyclable, not pinned).
    pub fn bytes_in_use(&self) -> usize {
        self.elems_in_use * std::mem::size_of::<f32>()
    }

    /// Ledger conservation: every buffer ever created is either in use or
    /// on the free list (buffers are never destroyed while the pool
    /// lives). The chaos battery asserts this after every injected fault
    /// schedule — a caught panic must not lose or double-count a page.
    pub fn conserved(&self) -> bool {
        self.pages_in_use + self.free_pages == self.created
    }
}

impl PagePool {
    pub fn new() -> Self {
        PagePool {
            shared: Arc::new(PoolShared { inner: Mutex::new(PoolInner::default()), faults: None }),
        }
    }

    /// A pool whose every allocation consults `faults` first (DESIGN.md
    /// §Faults). Production pools use [`PagePool::new`] and skip the seam
    /// entirely.
    pub fn with_faults(faults: Arc<dyn AllocFault>) -> Self {
        PagePool {
            shared: Arc::new(PoolShared {
                inner: Mutex::new(PoolInner::default()),
                faults: Some(faults),
            }),
        }
    }

    /// Allocate one zeroed page of `elems` f32s, reusing an exact-size
    /// free-list buffer when one exists.
    ///
    /// # Panics
    /// With [`ALLOC_FAIL_MSG`] when an injected fault fires — before the
    /// ledger lock is taken, so the accounting is untouched and the
    /// caller's `catch_unwind` sees a conserved pool.
    pub fn alloc(&self, elems: usize) -> Page {
        assert!(elems > 0, "page must hold at least one element");
        if let Some(f) = &self.shared.faults {
            if f.on_alloc() {
                std::panic::panic_any(ALLOC_FAIL_MSG);
            }
        }
        let mut inner = lock_inner(&self.shared.inner);
        let data = match inner.free.get_mut(&elems).and_then(Vec::pop) {
            Some(mut buf) => {
                inner.elems_free -= elems;
                buf.fill(0.0);
                buf
            }
            None => {
                inner.created += 1;
                vec![0.0f32; elems].into_boxed_slice()
            }
        };
        inner.pages_in_use += 1;
        inner.elems_in_use += elems;
        drop(inner);
        Page { buf: Arc::new(PageBuf { data, pool: Arc::downgrade(&self.shared) }) }
    }

    /// Current ledger snapshot.
    pub fn stats(&self) -> PoolStats {
        let inner = lock_inner(&self.shared.inner);
        PoolStats {
            pages_in_use: inner.pages_in_use,
            elems_in_use: inner.elems_in_use,
            free_pages: inner.free.values().map(Vec::len).sum(),
            elems_free: inner.elems_free,
            created: inner.created,
            freed: inner.freed,
        }
    }

    /// Do two handles settle against the same ledger?
    pub fn same_pool(&self, other: &PagePool) -> bool {
        Arc::ptr_eq(&self.shared, &other.shared)
    }
}

impl Default for PagePool {
    fn default() -> Self {
        Self::new()
    }
}

/// The refcounted page payload. `Drop` runs exactly once (when the last
/// [`Page`] handle goes away) and returns the buffer to its pool's free
/// list — unless the pool itself is already gone, in which case the
/// buffer just deallocates.
struct PageBuf {
    data: Box<[f32]>,
    pool: Weak<PoolShared>,
}

impl Drop for PageBuf {
    fn drop(&mut self) {
        if let Some(shared) = self.pool.upgrade() {
            let data = std::mem::take(&mut self.data);
            let elems = data.len();
            let mut inner = lock_inner(&shared.inner);
            inner.pages_in_use -= 1;
            inner.elems_in_use -= elems;
            inner.elems_free += elems;
            inner.freed += 1;
            inner.free.entry(elems).or_default().push(data);
        }
    }
}

/// One refcounted page. `Clone` shares (refcount bump, no copy);
/// [`Page::make_mut`] writes (in place when unique, copy-on-write when
/// shared).
#[derive(Clone)]
pub struct Page {
    buf: Arc<PageBuf>,
}

impl Page {
    pub fn as_slice(&self) -> &[f32] {
        &self.buf.data
    }

    pub fn elems(&self) -> usize {
        self.buf.data.len()
    }

    /// Live handles to this page (1 = unshared).
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.buf)
    }

    /// Stable identity of the underlying buffer — lets tests assert that
    /// a COW actually moved a handle to fresh storage (or that a
    /// frozen-prefix page never moved).
    pub fn buf_ptr(&self) -> *const f32 {
        self.buf.data.as_ptr()
    }

    /// Mutable access with copy-on-write: if any other handle shares the
    /// buffer, this handle is first repointed at a fresh pool page holding
    /// a copy, so the shared original is never mutated.
    pub fn make_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.buf).is_none() {
            let pool = PagePool {
                shared: self.buf.pool.upgrade().expect("page outlived its pool"),
            };
            let mut fresh = pool.alloc(self.buf.data.len());
            Arc::get_mut(&mut fresh.buf)
                .expect("freshly allocated page is unique")
                .data
                .copy_from_slice(&self.buf.data);
            *self = fresh;
        }
        &mut Arc::get_mut(&mut self.buf).expect("page is unique after COW").data
    }
}

/// A session's ordered view of its blocks for one cached tensor (one
/// head's K or V): block `i` lives at offset `(i % blocks_per_page) *
/// block_elems` of page `i / blocks_per_page`. Pages appear lazily as
/// blocks are first written; [`PageTable::fork`] shares every existing
/// page by refcount.
pub struct PageTable {
    pages: Vec<Page>,
    block_elems: usize,
    blocks_per_page: usize,
    pool: PagePool,
}

impl PageTable {
    pub fn new(pool: &PagePool, block_elems: usize, blocks_per_page: usize) -> Self {
        assert!(block_elems > 0, "block_elems must be positive");
        assert!(blocks_per_page > 0, "blocks_per_page must be positive");
        PageTable {
            pages: Vec::new(),
            block_elems,
            blocks_per_page,
            pool: pool.clone(),
        }
    }

    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    pub fn blocks_per_page(&self) -> usize {
        self.blocks_per_page
    }

    /// Elements per page.
    pub fn page_elems(&self) -> usize {
        self.block_elems * self.blocks_per_page
    }

    /// Read block `i` (its page must already exist — decode only ever
    /// reads blocks at or before the block it last wrote).
    pub fn block(&self, i: usize) -> &[f32] {
        let page = &self.pages[i / self.blocks_per_page];
        let off = (i % self.blocks_per_page) * self.block_elems;
        &page.as_slice()[off..off + self.block_elems]
    }

    /// Write block `i`, allocating its page on first touch and
    /// copy-on-writing it when shared with a forked session.
    pub fn block_mut(&mut self, i: usize) -> &mut [f32] {
        let p = i / self.blocks_per_page;
        while self.pages.len() <= p {
            self.pages.push(self.pool.alloc(self.page_elems()));
        }
        let off = (i % self.blocks_per_page) * self.block_elems;
        &mut self.pages[p].make_mut()[off..off + self.block_elems]
    }

    /// Share every resident page with a new table (refcount bumps only —
    /// no floats move until one side writes).
    pub fn fork(&self) -> Self {
        PageTable {
            pages: self.pages.clone(),
            block_elems: self.block_elems,
            blocks_per_page: self.blocks_per_page,
            pool: self.pool.clone(),
        }
    }

    /// Pages this table currently references (shared pages count once per
    /// table — the pool's `pages_in_use` counts them once globally).
    pub fn resident_pages(&self) -> usize {
        self.pages.len()
    }

    /// f32 elements reachable through this table.
    pub fn resident_elems(&self) -> usize {
        self.pages.len() * self.page_elems()
    }

    /// The page handles themselves — `tests/pages_props.rs` inspects
    /// refcounts and buffer identities through this.
    pub fn pages(&self) -> &[Page] {
        &self.pages
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    // The randomized-churn property suites live in tests/pages_props.rs;
    // these are the edge cases.
    use super::*;

    #[test]
    fn alloc_free_recycles_exact_sizes() {
        let pool = PagePool::new();
        let a = pool.alloc(8);
        let ptr = a.buf_ptr();
        drop(a);
        let s = pool.stats();
        assert_eq!((s.pages_in_use, s.free_pages, s.created, s.freed), (0, 1, 1, 1));
        // different size: must not reuse the freed 8-elem buffer
        let b = pool.alloc(4);
        assert_eq!(pool.stats().created, 2);
        drop(b);
        // same size: reused, zeroed
        let c = pool.alloc(8);
        assert_eq!(c.buf_ptr(), ptr, "exact-size free buffer must be recycled");
        assert!(c.as_slice().iter().all(|&x| x == 0.0));
        assert_eq!(pool.stats().created, 2, "recycling must not create");
    }

    #[test]
    fn injected_alloc_fault_panics_with_a_conserved_ledger() {
        struct FailSecond(std::sync::atomic::AtomicUsize);
        impl AllocFault for FailSecond {
            fn on_alloc(&self) -> bool {
                self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed) == 1
            }
        }
        let pool = PagePool::with_faults(Arc::new(FailSecond(Default::default())));
        let a = pool.alloc(8); // ordinal 0: fine
        let err = std::panic::catch_unwind(|| pool.alloc(8)).unwrap_err();
        assert_eq!(*err.downcast_ref::<&'static str>().unwrap(), ALLOC_FAIL_MSG);
        // the fault fired before the ledger lock: accounting untouched,
        // and the pool is still fully usable afterwards
        let s = pool.stats();
        assert_eq!((s.pages_in_use, s.created), (1, 1));
        assert!(s.conserved());
        let b = pool.alloc(8); // ordinal 2: fine again
        drop((a, b));
        let s = pool.stats();
        assert_eq!(s.pages_in_use, 0);
        assert!(s.conserved());
    }

    #[test]
    fn cow_never_mutates_a_shared_page() {
        let pool = PagePool::new();
        let mut a = pool.alloc(4);
        a.make_mut().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let b = a.clone();
        assert_eq!(a.ref_count(), 2);
        let shared_ptr = b.buf_ptr();
        a.make_mut()[0] = 9.0;
        assert_ne!(a.buf_ptr(), shared_ptr, "write to a shared page must COW");
        assert_eq!(b.as_slice(), &[1.0, 2.0, 3.0, 4.0], "sharer must see the original");
        assert_eq!(a.as_slice(), &[9.0, 2.0, 3.0, 4.0]);
        assert_eq!(pool.stats().pages_in_use, 2);
    }

    #[test]
    fn unique_pages_write_in_place() {
        let pool = PagePool::new();
        let mut a = pool.alloc(4);
        let ptr = a.buf_ptr();
        a.make_mut()[1] = 7.0;
        assert_eq!(a.buf_ptr(), ptr, "unique page must not move on write");
        assert_eq!(pool.stats().created, 1);
    }

    #[test]
    fn table_allocates_lazily_and_forks_by_refcount() {
        let pool = PagePool::new();
        let mut t = PageTable::new(&pool, 6, 2); // 2 blocks per page
        assert_eq!(t.resident_pages(), 0);
        t.block_mut(0)[0] = 1.0;
        assert_eq!(t.resident_pages(), 1, "block 0 and 1 share page 0");
        t.block_mut(1)[0] = 2.0;
        assert_eq!(t.resident_pages(), 1);
        t.block_mut(2)[0] = 3.0;
        assert_eq!(t.resident_pages(), 2);
        assert_eq!(pool.stats().pages_in_use, 2);

        let mut f = t.fork();
        assert_eq!(pool.stats().pages_in_use, 2, "fork must not allocate");
        assert_eq!(t.pages()[0].ref_count(), 2);
        // write through the fork: COWs its copy, original unmoved
        let orig = t.pages()[1].buf_ptr();
        f.block_mut(2)[1] = 9.0;
        assert_eq!(t.pages()[1].buf_ptr(), orig);
        assert_eq!(t.block(2)[1], 0.0);
        assert_eq!(f.block(2)[1], 9.0);
        assert_eq!(pool.stats().pages_in_use, 3);
        drop(f);
        assert_eq!(pool.stats().pages_in_use, 2);
        assert_eq!(pool.stats().free_pages, 1);
    }
}
