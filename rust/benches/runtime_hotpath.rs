//! Microbenchmarks of the L3 hot path (§Perf): literal upload, train-step
//! execute, eval execute, end-to-end step including data generation, and
//! server batch assembly. These numbers drive the EXPERIMENTS.md §Perf
//! iteration log.
//!
//! Run: cargo bench --bench runtime_hotpath [-- --exp NAME --iters N]

use sinkhorn::coordinator::TrainOptions;
use sinkhorn::data::TaskData;
use sinkhorn::runtime::{artifacts_dir, Experiment, Runtime};
use sinkhorn::util::cli::Args;
use sinkhorn::util::stats::{percentile, time_iters};

fn report(label: &str, secs: &mut [f64]) {
    let p50 = percentile(secs, 50.0) * 1e3;
    let p95 = percentile(secs, 95.0) * 1e3;
    let mean = secs.iter().sum::<f64>() / secs.len() as f64 * 1e3;
    println!("{label:<42} mean {mean:>8.3}ms  p50 {p50:>8.3}ms  p95 {p95:>8.3}ms");
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let name = args.str("exp", "lmw_tiny__sinkhorn_b16");
    let iters = args.usize("iters", 20)?;
    let artifacts = artifacts_dir();
    let rt = Runtime::cpu()?;
    let exp = Experiment::load(&artifacts, &name)?;
    let mut data = TaskData::for_experiment(&exp.manifest)?;
    println!("== runtime hot path: {name} ({} params) ==", exp.manifest.n_params());

    // 1. batch generation (pure rust data pipeline)
    let mut t = time_iters(3, iters, || {
        let _ = data.train_batch();
    });
    report("data: train_batch generation", &mut t);

    // 2. literal upload
    let batch = data.train_batch();
    let mut t = time_iters(3, iters, || {
        let _ = batch.iter().map(|b| b.to_literal().unwrap()).collect::<Vec<_>>();
    });
    report("runtime: host->literal upload", &mut t);

    // 3. train-step execute (graph already compiled after warmup)
    let mut state = exp.init_state(&rt, 1)?;
    let lits: Vec<_> = batch.iter().map(|b| b.to_literal().unwrap()).collect();
    let mut t = time_iters(2, iters, || {
        exp.train_step(&rt, &mut state, 1, &lits).unwrap();
    });
    report("runtime: train_step execute+state swap", &mut t);

    // 4. eval execute
    if let TaskData::Lm(d) = &mut data {
        let eval_batches = d.eval_batches(1);
        let elits: Vec<_> = eval_batches[0].iter().map(|b| b.to_literal().unwrap()).collect();
        let mut t = time_iters(2, iters, || {
            exp.eval(&rt, &state.params, &elits).unwrap();
        });
        report("runtime: eval execute", &mut t);
    }

    // 5. end-to-end step (data + upload + execute)
    let mut t = time_iters(1, iters, || {
        let b = data.train_batch();
        let l: Vec<_> = b.iter().map(|x| x.to_literal().unwrap()).collect();
        exp.train_step(&rt, &mut state, 2, &l).unwrap();
    });
    report("e2e: full train step", &mut t);

    // 6. training throughput over a short run (includes logging machinery)
    let mut d2 = TaskData::for_experiment(&exp.manifest)?;
    let opts = TrainOptions { steps: iters, seed: 3, log_every: 1000, verbose: false, checkpoint: None };
    let mut s2 = exp.init_state(&rt, 3)?;
    let t0 = std::time::Instant::now();
    sinkhorn::coordinator::train(&rt, &exp, &mut d2, &mut s2, &opts)?;
    let sps = iters as f64 / t0.elapsed().as_secs_f64();
    println!("{:<42} {sps:>8.2} steps/s", "coordinator: sustained training");
    Ok(())
}
