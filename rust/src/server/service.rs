//! Inference service: a router thread owns the execution backend (the
//! PJRT client is not `Send`-shareable, so all execution funnels through
//! one executor — the vllm-router shape: N frontends -> channel ->
//! batcher -> executor).
//!
//! Three verbs share the intake channel: **classify** (token ids in,
//! predicted label out), **generate** (prompt + token budget in, greedily
//! decoded ids out — optionally streamed token by token), and **info**
//! (the served model described as one `key=value` line).
//!
//! Two executor loops exist (DESIGN.md §Scheduler):
//!
//! * **Continuous-batching scheduler** ([`scheduler_loop`]) — the default
//!   for the pure-Rust fallback backend. A *session table* replaces
//!   request-batch waves: admission opens a per-request
//!   [`GenSession`] (bounded by slots and a real-memory budget from
//!   `memory::stack_decode_state_bytes`), every tick advances **all**
//!   active sessions by one token through one fused
//!   `(session, layer, head)` engine pass
//!   ([`FallbackModel::step_sessions`]), finished sessions retire and
//!   free their slot immediately, new requests join between ticks, and
//!   classify/info work interleaves between ticks instead of waiting
//!   behind a generation wave. Per-session output is **bit-identical** to
//!   single-request `generate` for any arrival order, slot count or
//!   thread count (`tests/decode_props.rs`).
//! * **Request-batch executor** ([`executor_loop`]) — the legacy wave
//!   loop: each gathered batch runs to completion. Still used by the
//!   artifact backend (the AOT-compiled XLA eval graph serves classify
//!   only; generate requests get a stable per-request error) and
//!   selectable for the fallback via [`ExecMode::RequestBatch`] (the
//!   `bench --target serve` baseline).
//!
//! The scheduler is additionally the serving stack's *failure boundary*
//! (DESIGN.md §Faults): generations carry cancellation tokens and
//! wall-clock deadlines, token streams ride a bounded per-connection
//! outbox the tick loop never blocks on (a slow reader pauses its own
//! session, then times out), per-session work runs under `catch_unwind`
//! so a poisoned session retires with a stable `error=` reply instead of
//! killing the executor, and shutdown drains in-flight sessions up to
//! the policy's drain window. Every retirement path — completion,
//! cancellation, deadline, stall, panic, drain abort — releases the
//! session's admission reservation and drops its decode state so the
//! page ledger returns to zero.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Checkpoint;
use crate::runtime::{Experiment, HostTensor, Runtime, TrainState};
use crate::sinkhorn::memory;

use super::batch::{gather, BatchPolicy, ExecMode};
use super::fallback::{FallbackConfig, FallbackModel, GenSession, StepOutcome};
use super::faults::panic_msg;

/// The stable message a generation gets when both the session slots and
/// the bounded wait queue are full — the TCP frontend renders it as the
/// `busy=` line (admission control, DESIGN.md §Scheduler).
pub const BUSY_MSG: &str = "generation queue full";

/// Stable error for a generation retired past its wall-clock deadline
/// (`--gen-deadline-ms` / the TCP `deadline=` option — DESIGN.md §Faults).
pub const DEADLINE_MSG: &str = "deadline exceeded";

/// Stable error for a generation cancelled by its client (disconnect
/// detected, or [`CancelToken::cancel`] called).
pub const CANCELLED_MSG: &str = "cancelled";

/// Stable error for a session whose client stopped reading: its bounded
/// outbox stayed full past the policy's stall timeout.
pub const STALL_MSG: &str = "slow client timeout";

/// Stable error for work refused or aborted by graceful drain shutdown.
pub const SHUTDOWN_MSG: &str = "server shutting down";

/// A streamed token event: `(index within the generation, token id)`.
pub type TokenEvent = (usize, i32);

/// Cooperative cancellation handle for one generation (DESIGN.md
/// §Faults). Cloneable; the frontend cancels when the client's socket
/// dies, the scheduler cancels when the token stream's receiver is
/// dropped, and the sweep at the top of every tick retires cancelled
/// sessions — releasing their reservation and freeing their pages.
#[derive(Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Per-request options for [`ServerHandle::generate_streaming_with`].
#[derive(Debug, Clone, Copy)]
pub struct GenOptions {
    /// Wall-clock budget from submit; overrides the policy's
    /// `gen_deadline` default. Overrunners retire with [`DEADLINE_MSG`].
    pub deadline: Option<Duration>,
    /// Capacity of the bounded token outbox between the scheduler and
    /// this stream's reader (min 1). When it is full the session pauses
    /// — the tick loop never blocks — until the reader catches up or the
    /// policy's stall timeout retires the session with [`STALL_MSG`].
    pub outbox: usize,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions { deadline: None, outbox: 64 }
    }
}

/// A streaming generation in flight: the token events, the final reply,
/// and the cancellation handle.
pub struct StreamingGen {
    /// `(index, id)` per generated token; closes before the reply lands.
    pub tokens: Receiver<TokenEvent>,
    /// The summary [`Response`] (or the stable error that retired the
    /// session).
    pub reply: Receiver<Result<Response>>,
    /// Cancel mid-generation: the session retires with [`CANCELLED_MSG`],
    /// its pages return to the pool, its reservation is released.
    pub cancel: CancelToken,
}

/// What a request asks the executor to do.
enum Work {
    Classify(Vec<i32>),
    Generate {
        tokens: Vec<i32>,
        max_new: usize,
        /// `Some`: the scheduler sends each token as it is produced into
        /// this bounded outbox (dropped at completion, before the summary
        /// reply). The request-batch loops don't stream — the sender is
        /// dropped at intake and all tokens arrive with the final
        /// [`Response`].
        stream: Option<SyncSender<TokenEvent>>,
        /// absolute wall-clock deadline (request `deadline=` option; the
        /// policy's `gen_deadline` default is applied at intake when
        /// `None`). The legacy request-batch loop ignores it.
        deadline: Option<Instant>,
        /// cooperative cancellation — swept at the top of every tick
        cancel: CancelToken,
    },
    /// report the served model's configuration (one `key=value` line)
    Info,
}

/// One inference request.
struct Request {
    work: Work,
    enqueued: Instant,
    resp: Sender<Result<Response>>,
}

/// Executor inbox message: a request, or an explicit stop. The sentinel
/// lets `shutdown` terminate the executor even while detached frontends
/// (e.g. the TCP acceptor) still hold live `ServerHandle` clones.
enum Msg {
    Req(Request),
    Stop,
}

/// Server reply.
#[derive(Debug, Clone)]
pub struct Response {
    /// classify: the predicted label. generate: the last generated token
    /// id (0 when the capacity-clamped budget came out empty) — the full
    /// sequence is in [`Response::gen`].
    pub label: i32,
    /// `Some(ids)` for generate requests: the newly generated token ids.
    pub gen: Option<Vec<i32>>,
    /// `Some(line)` for model-info requests: the served model described as
    /// one `key=value` line (depth/heads/config — the TCP `model` verb).
    pub info: Option<String>,
    /// time spent waiting before execution started (request-batch: in the
    /// batcher; scheduler generations: in the admission queue)
    pub queue: Duration,
    /// total time from submit to reply
    pub total: Duration,
    /// how many requests shared the executed batch (scheduler
    /// generations: sessions sharing the request's final tick)
    pub batch_size: usize,
}

/// Handle to a running server; cloneable across client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
    pub seq_len: usize,
}

impl ServerHandle {
    /// Blocking classify call.
    pub fn classify(&self, tokens: Vec<i32>) -> Result<Response> {
        self.submit(Work::Classify(tokens))
    }

    /// Blocking generate call: greedily decode up to `max_new` tokens
    /// after `tokens` (fallback backend only — see the module docs).
    pub fn generate(&self, tokens: Vec<i32>, max_new: usize) -> Result<Response> {
        self.submit(Work::Generate {
            tokens,
            max_new,
            stream: None,
            deadline: None,
            cancel: CancelToken::new(),
        })
    }

    /// Streaming generate: returns immediately with the token-event
    /// receiver and the final-reply receiver. Under the continuous
    /// scheduler each `(index, id)` arrives as its token is produced; the
    /// token channel closes (sender dropped) right before the final
    /// [`Response`] — carrying the full sequence — lands on the second
    /// receiver. Request-batch executors send no token events; the
    /// summary reply still arrives.
    pub fn generate_streaming(
        &self,
        tokens: Vec<i32>,
        max_new: usize,
    ) -> Result<(Receiver<TokenEvent>, Receiver<Result<Response>>)> {
        let sg = self.generate_streaming_with(tokens, max_new, GenOptions::default())?;
        Ok((sg.tokens, sg.reply))
    }

    /// [`Self::generate_streaming`] with per-request failure controls
    /// (DESIGN.md §Faults): a wall-clock deadline, the bounded-outbox
    /// capacity, and a [`CancelToken`] for mid-generation cancellation.
    pub fn generate_streaming_with(
        &self,
        tokens: Vec<i32>,
        max_new: usize,
        opts: GenOptions,
    ) -> Result<StreamingGen> {
        let (ttx, trx) = sync_channel(opts.outbox.max(1));
        let (rtx, rrx) = channel();
        let cancel = CancelToken::new();
        let enqueued = Instant::now();
        let req = Request {
            work: Work::Generate {
                tokens,
                max_new,
                stream: Some(ttx),
                deadline: opts.deadline.map(|d| enqueued + d),
                cancel: cancel.clone(),
            },
            enqueued,
            resp: rtx,
        };
        self.tx.send(Msg::Req(req)).map_err(|_| anyhow!("server stopped"))?;
        Ok(StreamingGen { tokens: trx, reply: rrx, cancel })
    }

    /// Blocking model-info call: the served model's configuration as one
    /// `key=value` line ([`Response::info`] — the TCP `model` verb).
    pub fn model_info(&self) -> Result<Response> {
        self.submit(Work::Info)
    }

    /// Begin graceful drain shutdown (DESIGN.md §Faults): the scheduler
    /// stops intake (new work gets the stable [`SHUTDOWN_MSG`] error),
    /// in-flight sessions may finish within the policy's drain window,
    /// survivors are then aborted with the same stable error, and the
    /// executor exits — observable via [`Server::is_finished`].
    pub fn begin_shutdown(&self) -> Result<()> {
        self.tx.send(Msg::Stop).map_err(|_| anyhow!("server stopped"))
    }

    fn submit(&self, work: Work) -> Result<Response> {
        let (rtx, rrx) = channel();
        let req = Request { work, enqueued: Instant::now(), resp: rtx };
        self.tx.send(Msg::Req(req)).map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// A running inference server (executor joins on drop of the handle + stop).
pub struct Server {
    pub handle: ServerHandle,
    join: Option<JoinHandle<Result<()>>>,
}

/// Reply to a generate request whose budget is zero: nothing to decode,
/// so it never occupies a worker or session slot — both executor loops
/// short-circuit it at intake, before admission.
fn reply_empty_generate(enqueued: Instant, resp: &Sender<Result<Response>>) {
    let _ = resp.send(Ok(Response {
        label: 0,
        gen: Some(Vec::new()),
        info: None,
        queue: Duration::ZERO,
        total: enqueued.elapsed(),
        batch_size: 1,
    }));
}

/// The request-batch executor: pull batches off the channel under
/// `policy`, split each batch by verb, hand classify rows to `classify`
/// and generate requests to `generate`, fan the results back out. The
/// artifact backend always runs this loop; the fallback runs it only
/// under [`ExecMode::RequestBatch`]. `generate: None` (the artifact
/// backend — its exported graphs have no decode entry) answers every
/// generate request with a stable per-request error instead of failing the
/// batch. Zero-budget generations short-circuit at intake; model-info
/// requests are answered from the precomputed `info` line without
/// touching the backend. Token rows are moved out of the requests (no
/// per-request copies on this path). Stream senders are dropped at
/// intake — this loop runs whole generations at once, so there is
/// nothing to stream.
fn executor_loop<C, G>(
    rx: &Receiver<Msg>,
    policy: &BatchPolicy,
    info: &str,
    mut classify: C,
    mut generate: Option<G>,
) -> Result<()>
where
    C: FnMut(&[Vec<i32>]) -> Result<Vec<i32>>,
    G: FnMut(&[(Vec<i32>, usize)]) -> Result<Vec<Vec<i32>>>,
{
    'serve: while let Some(msgs) = gather(rx, policy) {
        let mut stop = false;
        let mut cls_rows: Vec<Vec<i32>> = Vec::new();
        let mut cls_meta: Vec<(Instant, Sender<Result<Response>>)> = Vec::new();
        let mut gen_rows: Vec<(Vec<i32>, usize)> = Vec::new();
        let mut gen_meta: Vec<(Instant, Sender<Result<Response>>)> = Vec::new();
        let mut info_meta: Vec<(Instant, Sender<Result<Response>>)> = Vec::new();
        for m in msgs {
            match m {
                Msg::Req(r) => match r.work {
                    Work::Classify(tokens) => {
                        cls_rows.push(tokens);
                        cls_meta.push((r.enqueued, r.resp));
                    }
                    Work::Generate { tokens, max_new, stream, .. } => {
                        drop(stream); // no token streaming on this loop
                        if max_new == 0 {
                            reply_empty_generate(r.enqueued, &r.resp);
                        } else {
                            gen_rows.push((tokens, max_new));
                            gen_meta.push((r.enqueued, r.resp));
                        }
                    }
                    Work::Info => info_meta.push((r.enqueued, r.resp)),
                },
                Msg::Stop => stop = true,
            }
        }
        let n = cls_rows.len() + gen_rows.len() + info_meta.len();
        if n == 0 {
            if stop {
                break 'serve;
            }
            continue;
        }
        let exec_start = Instant::now();
        for (enqueued, resp) in info_meta {
            let _ = resp.send(Ok(Response {
                label: 0,
                gen: None,
                info: Some(info.to_string()),
                queue: exec_start - enqueued,
                total: enqueued.elapsed(),
                batch_size: n,
            }));
        }
        if !cls_rows.is_empty() {
            match classify(&cls_rows) {
                Ok(labels) => {
                    for (i, (enqueued, resp)) in cls_meta.into_iter().enumerate() {
                        let _ = resp.send(Ok(Response {
                            label: labels[i],
                            gen: None,
                            info: None,
                            queue: exec_start - enqueued,
                            total: enqueued.elapsed(),
                            batch_size: n,
                        }));
                    }
                }
                Err(e) => {
                    for (_, resp) in cls_meta {
                        let _ = resp.send(Err(anyhow!("exec failed: {e}")));
                    }
                }
            }
        }
        if !gen_rows.is_empty() {
            match &mut generate {
                None => {
                    for (_, resp) in gen_meta {
                        let _ = resp.send(Err(anyhow!(
                            "generate requires the pure-Rust fallback backend"
                        )));
                    }
                }
                Some(g) => match g(&gen_rows) {
                    Ok(seqs) => {
                        for (seq, (enqueued, resp)) in seqs.into_iter().zip(gen_meta) {
                            let _ = resp.send(Ok(Response {
                                label: seq.last().copied().unwrap_or(0),
                                gen: Some(seq),
                                info: None,
                                queue: exec_start - enqueued,
                                total: enqueued.elapsed(),
                                batch_size: n,
                            }));
                        }
                    }
                    Err(e) => {
                        for (_, resp) in gen_meta {
                            let _ = resp.send(Err(anyhow!("exec failed: {e}")));
                        }
                    }
                },
            }
        }
        if stop {
            break 'serve;
        }
    }
    Ok(())
}

/// One admitted generation in the scheduler's session table.
struct ActiveSession {
    sess: GenSession,
    enqueued: Instant,
    admitted: Instant,
    stream: Option<SyncSender<TokenEvent>>,
    resp: Sender<Result<Response>>,
    /// bytes this session reserved against `mem_budget` at admission
    /// (paged models only; 0 under worst-case slot budgeting) — returned
    /// to the pool accounting when the session retires
    reserved_bytes: usize,
    /// absolute wall-clock deadline; overrunners retire with
    /// [`DEADLINE_MSG`] at the next sweep
    deadline: Option<Instant>,
    /// cooperative cancellation (client disconnect, dropped receiver)
    cancel: CancelToken,
    /// a token the bounded outbox refused: the session is *paused* — it
    /// skips decode ticks until the retry flush lands the token or the
    /// stall timeout retires it. The tick loop itself never blocks.
    pending: Option<TokenEvent>,
    /// when the outbox first refused — the stall clock
    stalled_since: Option<Instant>,
}

/// One generation waiting in the bounded admission queue.
struct PendingGen {
    tokens: Vec<i32>,
    max_new: usize,
    stream: Option<SyncSender<TokenEvent>>,
    enqueued: Instant,
    resp: Sender<Result<Response>>,
    deadline: Option<Instant>,
    cancel: CancelToken,
}

/// Retire a finished session: close its token stream, then send the
/// summary reply carrying the full generation. `tick_n` is how many
/// sessions shared the retiring tick (reported as `batch_size`).
fn finish_session(a: ActiveSession, tick_n: usize) {
    let ActiveSession { sess, enqueued, admitted, stream, resp, .. } = a;
    drop(stream); // token channel closes before the summary reply
    let gen = sess.into_generated();
    let _ = resp.send(Ok(Response {
        label: gen.last().copied().unwrap_or(0),
        gen: Some(gen),
        info: None,
        queue: admitted - enqueued,
        total: enqueued.elapsed(),
        batch_size: tick_n,
    }));
}

/// Retire a session that will not complete (cancelled, past deadline,
/// stalled, poisoned, or drain-aborted): close its token stream, drop
/// its decode state — the pages return to the pool here — and send the
/// stable error as the summary. The caller releases its reservation.
fn fail_session(a: ActiveSession, msg: &'static str) {
    let ActiveSession { sess, stream, resp, .. } = a;
    drop(stream);
    drop(sess);
    let _ = resp.send(Err(anyhow!("{msg}")));
}

/// Refuse a queued generation with a stable error.
fn fail_pending(p: &PendingGen, msg: &'static str) {
    let _ = p.resp.send(Err(anyhow!("{msg}")));
}

/// The continuous-batching decode scheduler (DESIGN.md §Scheduler).
///
/// Each loop iteration is one *tick*:
///
/// 1. **Intake** — block in the dynamic batcher only while the session
///    table is idle; otherwise drain up to `max_batch` waiting messages
///    without blocking. Zero-budget generations reply immediately;
///    arrivals beyond `slots + queue_depth` in flight get the stable
///    [`BUSY_MSG`] error (the TCP `busy=` line).
/// 2. **Admission** — free slots pull from the FIFO wait queue; a
///    session's prompt (prefill) flows through the same per-tick stepping
///    as decode, so long prompts never stall other sessions.
/// 3. **Classify/info interleave** — classify rows gathered this tick run
///    as one batch between decode ticks instead of waiting behind a
///    generation wave.
/// 4. **Chunked prefill** (when `policy.prefill_chunk_tokens > 0`,
///    DESIGN.md §Prefill) — sessions still consuming their prompt absorb
///    up to the chunk budget of it through the block-parallel engine
///    path ([`FallbackModel::prefill_session`]) before the tick;
///    bit-identical to per-tick stepping, Sarathi-style bounded.
/// 5. **Decode tick** — every active session advances one token through
///    one fused `(session, layer, head)` engine pass; emitted tokens go
///    to stream subscribers; finished sessions retire and free their slot
///    immediately.
///
/// Admission is in terms of the real decode-state bytes each session
/// pins. Monolithic models budget worst-case: slots =
/// `memory::admitted_sessions(policy.mem_budget,
/// model.session_state_bytes(), policy.max_sessions)`, fixed up front.
/// Paged models (DESIGN.md §Pages) instead *reserve* per session at
/// admission time — [`FallbackModel::session_admission_bytes`], the
/// analytic resident peak at the session's actual clamped length minus
/// the pages a cached prompt prefix already holds — so short requests
/// and shared-prefix cohorts admit where worst-case budgeting would
/// refuse them. One session always admits into an idle table (the
/// floor-1 progress guarantee), and retirements return their
/// reservation mid-wave, draining the wait queue under page pressure.
/// Reservations ride [`memory::Reservations`], so an unbalanced
/// retirement path is a hard error, not a slow leak.
///
/// Failure handling (DESIGN.md §Faults) is woven into the tick:
///
/// * a **sweep** between intake and admission retires cancelled
///   sessions, deadline overrunners, and outbox stalls — queued and
///   active alike — each with its stable error;
/// * token emission uses `try_send` into the bounded outbox: a refused
///   token *pauses* that session (it holds its slot but skips ticks)
///   until the retry flush lands it or the stall timeout fires;
/// * `open_session`, `classify_batch` and the decode tick
///   ([`FallbackModel::step_sessions_isolated`]) run under panic
///   containment: a poisoned request gets a stable `error=` reply and a
///   clean retirement, the loop keeps serving;
/// * after `Stop` (or all handles dropping) the loop refuses new work
///   with [`SHUTDOWN_MSG`], drains in-flight sessions up to
///   `policy.drain`, aborts survivors with the same stable error, and
///   exits with every reservation released.
fn scheduler_loop(
    rx: &Receiver<Msg>,
    policy: &BatchPolicy,
    info: &str,
    model: &FallbackModel,
) -> Result<()> {
    let slot_cap = policy.max_sessions.max(1);
    let paged_budget = model.paged() && policy.mem_budget > 0;
    let slots = if paged_budget {
        slot_cap // bytes are reserved per admission below, not pre-divided
    } else {
        memory::admitted_sessions(policy.mem_budget, model.session_state_bytes(), slot_cap)
    };
    let mut reservations =
        memory::Reservations::new(if paged_budget { policy.mem_budget } else { 0 });
    let mut scratch = model.new_batch_scratch();
    // chunked-prefill scratch, materialized on first use so schedulers
    // running the legacy step-prefill path (chunk budget 0) never pay
    // for the per-session chunk buffers (DESIGN.md §Prefill)
    let mut prefill_scratch = None;
    let mut active: Vec<ActiveSession> = Vec::with_capacity(slots);
    let mut waiting: VecDeque<PendingGen> = VecDeque::new();
    let mut stop = false;
    let mut drain_deadline: Option<Instant> = None;
    'serve: loop {
        // 1. intake — block only while the session table is idle and the
        // server is live; otherwise drain without blocking (during drain
        // the messages are still pulled so refusals reply immediately)
        let mut msgs: Vec<Msg> = Vec::new();
        if !stop && active.is_empty() && waiting.is_empty() {
            match gather(rx, policy) {
                Some(m) => msgs = m,
                None => break 'serve,
            }
        } else {
            while msgs.len() < policy.max_batch {
                match rx.try_recv() {
                    Ok(m) => msgs.push(m),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        stop = true;
                        break;
                    }
                }
            }
        }
        let tick_start = Instant::now();
        let mut progressed = !msgs.is_empty();
        let mut cls_rows: Vec<Vec<i32>> = Vec::new();
        let mut cls_meta: Vec<(Instant, Sender<Result<Response>>)> = Vec::new();
        for m in msgs {
            match m {
                Msg::Req(r) => {
                    if stop {
                        // intake is closed: every verb gets the stable
                        // drain refusal, in-flight work is unaffected
                        let _ = r.resp.send(Err(anyhow!("{SHUTDOWN_MSG}")));
                        continue;
                    }
                    match r.work {
                        Work::Classify(tokens) => {
                            cls_rows.push(tokens);
                            cls_meta.push((r.enqueued, r.resp));
                        }
                        Work::Info => {
                            let _ = r.resp.send(Ok(Response {
                                label: 0,
                                gen: None,
                                info: Some(info.to_string()),
                                queue: tick_start - r.enqueued,
                                total: r.enqueued.elapsed(),
                                batch_size: 1,
                            }));
                        }
                        Work::Generate { tokens, max_new, stream, deadline, cancel } => {
                            if max_new == 0 {
                                drop(stream);
                                reply_empty_generate(r.enqueued, &r.resp);
                            } else if active.len() + waiting.len() >= slots + policy.queue_depth
                            {
                                drop(stream);
                                let _ = r.resp.send(Err(anyhow!("{}", BUSY_MSG)));
                            } else {
                                waiting.push_back(PendingGen {
                                    tokens,
                                    max_new,
                                    stream,
                                    enqueued: r.enqueued,
                                    resp: r.resp,
                                    // the policy's default deadline applies
                                    // from arrival, not admission
                                    deadline: deadline
                                        .or(policy.gen_deadline.map(|d| r.enqueued + d)),
                                    cancel,
                                });
                            }
                        }
                    }
                }
                Msg::Stop => stop = true,
            }
        }
        if stop {
            drain_deadline.get_or_insert(tick_start + policy.drain);
        }
        // 2. sweep — cancellations, deadline expiries and outbox stalls
        // retire before admission so expired queued work never opens a
        // session and dead active sessions free their slot, reservation
        // and pages right here
        let now = Instant::now();
        waiting.retain(|p| {
            let msg = if p.cancel.is_cancelled() {
                CANCELLED_MSG
            } else if p.deadline.is_some_and(|d| now >= d) {
                DEADLINE_MSG
            } else {
                return true;
            };
            fail_pending(p, msg);
            false
        });
        let mut i = 0;
        while i < active.len() {
            let a = &active[i];
            let msg = if a.cancel.is_cancelled() {
                Some(CANCELLED_MSG)
            } else if a.deadline.is_some_and(|d| now >= d) {
                Some(DEADLINE_MSG)
            } else if a
                .stalled_since
                .is_some_and(|t| now.duration_since(t) >= policy.stall_timeout)
            {
                Some(STALL_MSG)
            } else {
                None
            };
            match msg {
                Some(msg) => {
                    let a = active.remove(i);
                    reservations.release(a.reserved_bytes);
                    fail_session(a, msg);
                    progressed = true;
                }
                None => i += 1,
            }
        }
        // 3. admission: free slots pull from the bounded wait queue; a
        // paged model charges each session's actual byte reservation
        // against the budget (floor one session into an idle table so
        // the server always makes progress) instead of pre-divided
        // worst-case slots. `open_session` is contained: a panic during
        // prefill (e.g. an injected allocation failure) unwinds the
        // half-built state — its pages return on drop — and fails that
        // request alone.
        while active.len() < slots {
            let Some(p) = waiting.front() else { break };
            let need = if paged_budget {
                model.session_admission_bytes(&p.tokens, p.max_new)
            } else {
                0
            };
            if paged_budget && !active.is_empty() && !reservations.fits(need) {
                break; // FIFO head waits for retirements to free pages
            }
            let p = waiting.pop_front().expect("front was Some");
            let sess =
                match catch_unwind(AssertUnwindSafe(|| model.open_session(&p.tokens, p.max_new)))
                {
                    Ok(sess) => sess,
                    Err(payload) => {
                        fail_pending(&p, panic_msg(&*payload));
                        progressed = true;
                        continue;
                    }
                };
            let a = ActiveSession {
                sess,
                enqueued: p.enqueued,
                admitted: Instant::now(),
                stream: p.stream,
                resp: p.resp,
                reserved_bytes: need,
                deadline: p.deadline,
                cancel: p.cancel,
                pending: None,
                stalled_since: None,
            };
            if a.sess.done() {
                // budget clamped to zero by a capacity-filled model:
                // nothing to tick, retire straight from admission
                finish_session(a, 1);
            } else {
                reservations.reserve(need);
                active.push(a);
            }
            progressed = true;
        }
        // 4. classify/info interleave between ticks, contained: a panic
        // fails this batch's requests with a stable error, not the loop
        if !cls_rows.is_empty() {
            let n = cls_rows.len();
            match catch_unwind(AssertUnwindSafe(|| model.classify_batch(&cls_rows))) {
                Ok(labels) => {
                    for (label, (enqueued, resp)) in labels.into_iter().zip(cls_meta) {
                        let _ = resp.send(Ok(Response {
                            label,
                            gen: None,
                            info: None,
                            queue: tick_start - enqueued,
                            total: enqueued.elapsed(),
                            batch_size: n,
                        }));
                    }
                }
                Err(payload) => {
                    let msg = panic_msg(&*payload);
                    for (_, resp) in cls_meta {
                        let _ = resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
            progressed = true;
        }
        // 5. retry flush: paused sessions try their refused token again
        // before the tick so a recovered reader resumes immediately
        for a in active.iter_mut() {
            let Some(ev) = a.pending.take() else { continue };
            let Some(tx) = a.stream.as_ref() else { continue };
            match tx.try_send(ev) {
                Ok(()) => {
                    a.stalled_since = None;
                    progressed = true;
                }
                Err(TrySendError::Full(ev)) => a.pending = Some(ev),
                Err(TrySendError::Disconnected(_)) => a.cancel.cancel(),
            }
        }
        // 6. budgeted chunked prefill (DESIGN.md §Prefill): sessions
        // still consuming their prompt absorb up to
        // `prefill_chunk_tokens` of it through the block-parallel engine
        // path before the tick, so a long prompt costs ℓ/chunk fused
        // passes instead of ℓ ticks — while the budget bounds how long
        // any one chunk holds the loop, so admitting a long-prompt
        // session never stalls active sessions' token cadence beyond it
        // (Sarathi-style chunking). Streams are bit-identical either
        // way. A panic mid-chunk is contained per session: replay to the
        // committed point recovers transient faults bitwise; a persistent
        // fault retires the session with its stable error (§Faults).
        if policy.prefill_chunk_tokens > 0 {
            let ps = prefill_scratch.get_or_insert_with(|| model.new_prefill_scratch());
            let mut failed: Vec<(usize, &'static str)> = Vec::new();
            for (i, a) in active.iter_mut().enumerate() {
                if a.pending.is_some() || a.sess.done() || a.sess.prefill_remaining() == 0 {
                    continue;
                }
                let r = catch_unwind(AssertUnwindSafe(|| {
                    model.prefill_session(&mut a.sess, policy.prefill_chunk_tokens, ps)
                }));
                match r {
                    Ok(n) => progressed |= n > 0,
                    Err(_) => {
                        match catch_unwind(AssertUnwindSafe(|| model.replay_prefill(&mut a.sess)))
                        {
                            Ok(()) => progressed = true,
                            Err(payload) => failed.push((i, panic_msg(&*payload))),
                        }
                    }
                }
            }
            for (i, msg) in failed.into_iter().rev() {
                let a = active.remove(i);
                reservations.release(a.reserved_bytes);
                fail_session(a, msg);
                progressed = true;
            }
        }
        // 7. one decode tick: every unpaused active session advances one
        // token through the isolated step path — a panic retires the
        // poisoned session(s) with stable errors, survivors keep their
        // bitwise streams (DESIGN.md §Faults)
        let mut idx: Vec<usize> = Vec::new();
        let outcomes = {
            let mut live: Vec<&mut GenSession> = Vec::new();
            for (i, a) in active.iter_mut().enumerate() {
                if a.pending.is_none() && !a.sess.done() {
                    idx.push(i);
                    live.push(&mut a.sess);
                }
            }
            model.step_sessions_isolated(&mut live, &mut scratch)
        };
        let tick_n = idx.len();
        let mut failed: Vec<(usize, &'static str)> = Vec::new();
        for (&i, &o) in idx.iter().zip(&outcomes) {
            progressed = true;
            match o {
                StepOutcome::Failed(msg) => failed.push((i, msg)),
                StepOutcome::Token(None) => {}
                StepOutcome::Token(Some(id)) => {
                    let a = &mut active[i];
                    let Some(tx) = a.stream.as_ref() else { continue };
                    let ev = (a.sess.generated().len() - 1, id);
                    match tx.try_send(ev) {
                        Ok(()) => {}
                        Err(TrySendError::Full(ev)) => {
                            // outbox full: pause the session, start the
                            // stall clock — never block the tick loop
                            a.pending = Some(ev);
                            a.stalled_since.get_or_insert(Instant::now());
                        }
                        Err(TrySendError::Disconnected(_)) => a.cancel.cancel(),
                    }
                }
            }
        }
        // poisoned sessions retire with their stable error (descending
        // index keeps the remaining indices valid)
        for (i, msg) in failed.into_iter().rev() {
            let a = active.remove(i);
            reservations.release(a.reserved_bytes);
            fail_session(a, msg);
        }
        // 8. retire finished sessions immediately — their slot frees for
        // the next admission pass; a done session still holding a refused
        // token stays until its flush lands (or its stall timeout fires)
        let mut i = 0;
        while i < active.len() {
            if active[i].sess.done() && active[i].pending.is_none() {
                let a = active.remove(i);
                reservations.release(a.reserved_bytes);
                finish_session(a, tick_n.max(1));
                progressed = true;
            } else {
                i += 1;
            }
        }
        // 9. drain: past the deadline, survivors abort with the stable
        // shutdown error — reservations released, pages freed
        if drain_deadline.is_some_and(|d| Instant::now() >= d) {
            for p in waiting.drain(..) {
                fail_pending(&p, SHUTDOWN_MSG);
            }
            for a in active.drain(..) {
                reservations.release(a.reserved_bytes);
                fail_session(a, SHUTDOWN_MSG);
            }
        }
        if stop && active.is_empty() && waiting.is_empty() {
            break 'serve;
        }
        if !progressed {
            // every session paused (or only future deadlines pending):
            // don't spin the intake drain hot
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    debug_assert!(reservations.is_empty(), "scheduler exited with unreleased reservations");
    Ok(())
}

impl Server {
    /// Start a server for `exp_name`: the artifact-backed executor when
    /// the compiled HLO artifacts and a PJRT runtime are available,
    /// otherwise the pure-Rust fallback engine (unless a checkpoint was
    /// requested — checkpoints only restore into artifact graphs).
    pub fn start(
        artifacts: PathBuf,
        exp_name: String,
        checkpoint: Option<PathBuf>,
        policy: BatchPolicy,
        init_seed: i32,
    ) -> Result<Server> {
        // a present registry means the operator *has* artifacts: a bad
        // experiment name or corrupt manifest must then fail loudly, not
        // silently demote to the untrained fallback model. Runtime (PJRT)
        // startup failures still fall back — the offline-stub case.
        let artifacts_present = artifacts.join("registry.json").exists();
        // start_artifact reports executor startup failures (missing
        // manifest, stub/broken PJRT runtime, bad artifacts) synchronously
        match Self::start_artifact(
            artifacts,
            exp_name.clone(),
            checkpoint.clone(),
            policy,
            init_seed,
        ) {
            Ok(server) => Ok(server),
            Err(e) if checkpoint.is_some() => {
                Err(e.context(format!("'{exp_name}' needs its artifacts to restore a checkpoint")))
            }
            // "server runtime" is the context start_artifact puts on the
            // PJRT construction failure — the one artifact-present error
            // that legitimately falls back
            Err(e) if artifacts_present && !format!("{e:#}").contains("server runtime") => {
                Err(e.context(format!(
                    "experiment '{exp_name}' failed to start (artifacts are present, so not \
                     falling back — check the name with `sinkhorn list`)"
                )))
            }
            Err(e) => {
                eprintln!(
                    "[server] no usable HLO artifact for '{exp_name}' ({e:#}); \
                     serving with the pure-Rust fallback engine"
                );
                let cfg = FallbackConfig { seed: init_seed as u64, ..Default::default() };
                Self::start_fallback(cfg, policy)
            }
        }
    }

    /// Artifact-backed executor: loads the experiment, restores or inits
    /// parameters, then serves until all handles are dropped. The
    /// executor thread owns the PJRT runtime (it is not `Send`); its
    /// startup outcome is funneled back over a channel so failures
    /// surface here without constructing a throwaway probe runtime.
    fn start_artifact(
        artifacts: PathBuf,
        exp_name: String,
        checkpoint: Option<PathBuf>,
        policy: BatchPolicy,
        init_seed: i32,
    ) -> Result<Server> {
        let probe = Experiment::load(&artifacts, &exp_name)?;
        if probe.manifest.eval_outputs.len() < 3 {
            bail!("experiment '{exp_name}' has no pred output; re-run make artifacts");
        }
        let seq_len = probe.manifest.eval_batch_inputs[0].shape[1];
        let graph_batch = probe.manifest.eval_batch_inputs[0].shape[0];
        let policy = policy.clamped(graph_batch);

        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::spawn(move || -> Result<()> {
            // executor startup: anything failing here aborts the server
            // before it accepts traffic (reported via ready_tx)
            let startup = || -> Result<(Runtime, Experiment, TrainState)> {
                let rt = Runtime::cpu().context("server runtime")?;
                let exp = Experiment::load(&artifacts, &exp_name)?;
                let state = match checkpoint {
                    Some(path) => Checkpoint::load(&path)?.restore(&exp.manifest)?,
                    None => exp.init_state(&rt, init_seed)?,
                };
                // warm the compile cache before accepting traffic
                let zeros =
                    HostTensor::i32(&[graph_batch, seq_len], vec![0; graph_batch * seq_len]);
                let zlabels = HostTensor::i32(&[graph_batch], vec![0; graph_batch]);
                exp.eval(&rt, &state.params, &[zeros.to_literal()?, zlabels.to_literal()?])?;
                Ok((rt, exp, state))
            };
            let (rt, exp, state) = match startup() {
                Ok(x) => {
                    let _ = ready_tx.send(Ok(()));
                    x
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return Ok(()); // failure already reported to the caller
                }
            };

            let info = format!(
                "backend=artifact exp={} seq_len={} graph_batch={} verbs=classify",
                exp_name, seq_len, graph_batch
            );
            executor_loop(
                &rx,
                &policy,
                &info,
                |rows| {
                    // assemble fixed-shape tensors, padding unused rows
                    let mut toks = Vec::with_capacity(graph_batch * seq_len);
                    for r in rows {
                        let take = r.len().min(seq_len);
                        toks.extend_from_slice(&r[..take]);
                        toks.resize(toks.len() + (seq_len - take), 0);
                    }
                    toks.resize(graph_batch * seq_len, 0);
                    let labels = vec![0i32; graph_batch];
                    let t_tok = HostTensor::i32(&[graph_batch, seq_len], toks);
                    let t_lab = HostTensor::i32(&[graph_batch], labels);
                    let out =
                        exp.eval(&rt, &state.params, &[t_tok.to_literal()?, t_lab.to_literal()?])?;
                    let pred = HostTensor::from_literal(&out[2])?;
                    Ok(pred.as_i32()?[..rows.len()].to_vec())
                },
                // the exported eval graphs have no incremental decode
                // entry; generate requests get per-request errors
                None::<fn(&[(Vec<i32>, usize)]) -> Result<Vec<Vec<i32>>>>,
            )
        });

        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Server { handle: ServerHandle { tx, seq_len }, join: Some(join) }),
            Ok(Err(e)) => {
                let _ = join.join();
                Err(e)
            }
            Err(_) => {
                let _ = join.join();
                bail!("server executor died during startup")
            }
        }
    }

    /// Pure-Rust executor on the blocked engine — works with no artifacts
    /// directory at all. Runs the continuous-batching scheduler by
    /// default; [`ExecMode::RequestBatch`] selects the legacy wave
    /// executor (module docs).
    pub fn start_fallback(cfg: FallbackConfig, policy: BatchPolicy) -> Result<Server> {
        // build the model synchronously so config errors surface here
        Server::start_fallback_model(FallbackModel::new(cfg)?, policy)
    }

    /// Like [`Server::start_fallback`], but takes a pre-built model —
    /// callers (fault-injection tests, chiefly) can wire a
    /// [`super::faults::FaultPlan`] via [`FallbackModel::with_faults`]
    /// and clone the page-pool handle before the model moves into the
    /// executor thread.
    pub fn start_fallback_model(model: FallbackModel, policy: BatchPolicy) -> Result<Server> {
        let seq_len = model.cfg.seq_len;
        let (tx, rx) = channel::<Msg>();
        let join = std::thread::spawn(move || -> Result<()> {
            let info = model.describe();
            match policy.mode {
                ExecMode::Continuous => scheduler_loop(&rx, &policy, &info, &model),
                ExecMode::RequestBatch => executor_loop(
                    &rx,
                    &policy,
                    &info,
                    |rows| Ok(model.classify_batch(rows)),
                    Some(|reqs: &[(Vec<i32>, usize)]| Ok(model.generate_batch(reqs))),
                ),
            }
        });
        Ok(Server { handle: ServerHandle { tx, seq_len }, join: Some(join) })
    }

    /// True once the executor thread has exited — after a drain
    /// completes, every in-flight session has been retired and the
    /// server is safe to drop without losing replies.
    pub fn is_finished(&self) -> bool {
        self.join.as_ref().is_none_or(|j| j.is_finished())
    }

    /// Close the intake channel and wait for the executor to drain.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.handle.tx.send(Msg::Stop);
        drop(self.handle);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fallback backend end to end: concurrent clients, batching,
    /// deterministic labels — all without artifacts or XLA.
    #[test]
    fn fallback_server_classifies_concurrently() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let policy =
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3), ..Default::default() };
        let server = Server::start_fallback(cfg.clone(), policy).unwrap();
        assert_eq!(server.handle.seq_len, 32);
        let mut joins = Vec::new();
        for t in 0..3i32 {
            let h = server.handle.clone();
            joins.push(std::thread::spawn(move || {
                (0..6)
                    .map(|i| {
                        let toks: Vec<i32> = (0..32).map(|p| p * 13 + t * 7 + i).collect();
                        let resp = h.classify(toks).unwrap();
                        assert!(resp.batch_size >= 1);
                        resp.label
                    })
                    .collect::<Vec<i32>>()
            }));
        }
        let labels: Vec<Vec<i32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        server.shutdown().unwrap();
        // replies must be deterministic: same requests against a fresh
        // server give identical labels
        let server2 = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        for (t, row) in labels.iter().enumerate() {
            for (i, &want) in row.iter().enumerate() {
                let toks: Vec<i32> = (0..32).map(|p| p * 13 + (t as i32) * 7 + i as i32).collect();
                assert_eq!(server2.handle.classify(toks).unwrap().label, want);
            }
        }
        server2.shutdown().unwrap();
    }

    /// The generate verb end to end through the continuous scheduler:
    /// tokens come back, match the bare model exactly, and classify still
    /// works beside it.
    #[test]
    fn fallback_server_generates() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg.clone(), BatchPolicy::default()).unwrap();
        let prompt: Vec<i32> = (0..8).map(|i| i * 3).collect();
        let r = server.handle.generate(prompt.clone(), 5).unwrap();
        let toks = r.gen.clone().expect("generate reply carries tokens");
        assert_eq!(toks.len(), 5);
        assert_eq!(r.label, *toks.last().unwrap());
        let model = FallbackModel::new(cfg).unwrap();
        assert_eq!(model.generate(&prompt, 5), toks);
        let c = server.handle.classify(prompt).unwrap();
        assert!(c.label >= 0 && c.gen.is_none());
        server.shutdown().unwrap();
    }

    /// The legacy request-batch executor stays selectable and correct.
    #[test]
    fn request_batch_mode_still_serves_both_verbs() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let policy = BatchPolicy { mode: ExecMode::RequestBatch, ..Default::default() };
        let server = Server::start_fallback(cfg.clone(), policy).unwrap();
        let model = FallbackModel::new(cfg).unwrap();
        let prompt: Vec<i32> = (0..6).map(|i| i * 5 + 1).collect();
        let r = server.handle.generate(prompt.clone(), 4).unwrap();
        assert_eq!(r.gen.unwrap(), model.generate(&prompt, 4));
        assert_eq!(server.handle.classify(prompt.clone()).unwrap().label, model.classify(&prompt));
        // zero-budget short-circuit applies on this loop too
        let z = server.handle.generate(prompt, 0).unwrap();
        assert_eq!(z.gen.unwrap(), Vec::<i32>::new());
        server.shutdown().unwrap();
    }

    /// Concurrent generations with mixed prompt/budget lengths multiplex
    /// through the session table and each reproduce single-request
    /// generation exactly — the scheduler's oracle contract, end to end.
    #[test]
    fn scheduler_multiplexes_concurrent_generations_exactly() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let policy = BatchPolicy {
            max_sessions: 3, // fewer slots than clients: queueing + reuse
            max_wait: Duration::from_millis(1),
            ..Default::default()
        };
        let server = Server::start_fallback(cfg.clone(), policy).unwrap();
        let model = FallbackModel::new(cfg).unwrap();
        let mut joins = Vec::new();
        for t in 0..6i32 {
            let h = server.handle.clone();
            joins.push(std::thread::spawn(move || {
                let prompt: Vec<i32> = (0..(3 + t % 4)).map(|i| i * 7 + t).collect();
                let max_new = 3 + (t as usize % 5);
                let r = h.generate(prompt.clone(), max_new).unwrap();
                (prompt, max_new, r.gen.unwrap())
            }));
        }
        for j in joins {
            let (prompt, max_new, got) = j.join().unwrap();
            assert_eq!(got, model.generate(&prompt, max_new), "prompt {prompt:?}");
        }
        server.shutdown().unwrap();
    }

    /// Streaming: every token arrives as an `(index, id)` event, in
    /// order, the channel closes before the summary reply, and the events
    /// reassemble the final generation exactly.
    #[test]
    fn scheduler_streams_tokens_in_order() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg.clone(), BatchPolicy::default()).unwrap();
        let prompt: Vec<i32> = (0..5).map(|i| i * 11).collect();
        let (toks, resp) = server.handle.generate_streaming(prompt.clone(), 6).unwrap();
        let events: Vec<TokenEvent> = toks.iter().collect(); // ends on sender drop
        let r = resp.recv().unwrap().unwrap();
        let full = r.gen.unwrap();
        assert_eq!(full.len(), 6);
        assert_eq!(events.len(), full.len());
        for (i, (idx, id)) in events.iter().enumerate() {
            assert_eq!(*idx, i, "token indices must stream in order");
            assert_eq!(*id, full[i], "streamed ids must match the summary");
        }
        let model = FallbackModel::new(cfg).unwrap();
        assert_eq!(full, model.generate(&prompt, 6));
        server.shutdown().unwrap();
    }

    /// `max_new == 0` short-circuits before admission: an empty reply,
    /// no session slot consumed (unit test for the intake rule).
    #[test]
    fn zero_budget_generate_short_circuits() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let r = server.handle.generate(vec![1, 2, 3], 0).unwrap();
        assert_eq!(r.gen, Some(Vec::new()));
        assert_eq!(r.label, 0);
        assert_eq!(r.batch_size, 1);
        // the server is still fully live afterwards
        assert_eq!(server.handle.generate(vec![1, 2, 3], 2).unwrap().gen.unwrap().len(), 2);
        server.shutdown().unwrap();
    }

    /// Admission control: with one slot and a zero-depth wait queue, a
    /// second in-flight generation gets the stable busy error while the
    /// first still completes.
    #[test]
    fn overflowing_admission_gets_busy_error() {
        let cfg = FallbackConfig { seq_len: 64, d_model: 16, nb: 4, ..Default::default() };
        let policy = BatchPolicy {
            max_sessions: 1,
            queue_depth: 0,
            max_wait: Duration::from_millis(20),
            ..Default::default()
        };
        let server = Server::start_fallback(cfg, policy).unwrap();
        // a long generation occupies the only slot for many ticks...
        let (_t1, r1) = server.handle.generate_streaming(vec![5], 60).unwrap();
        // ...so the next arrival can neither be admitted nor queued
        let (_t2, r2) = server.handle.generate_streaming(vec![6], 4).unwrap();
        let e = r2.recv().unwrap().unwrap_err();
        assert_eq!(e.to_string(), BUSY_MSG);
        let first = r1.recv().unwrap().unwrap();
        assert_eq!(first.gen.unwrap().len(), 60);
        server.shutdown().unwrap();
    }

    /// A tiny memory budget clamps to the one-slot floor and still serves.
    #[test]
    fn memory_budget_floor_still_serves() {
        let cfg = FallbackConfig { seq_len: 32, d_model: 16, nb: 4, ..Default::default() };
        let policy = BatchPolicy { mem_budget: 1, ..Default::default() };
        let server = Server::start_fallback(cfg, policy).unwrap();
        let r = server.handle.generate(vec![3, 1, 4], 3).unwrap();
        assert_eq!(r.gen.unwrap().len(), 3);
        server.shutdown().unwrap();
    }

    /// The model-info verb end to end: the reply carries the fallback
    /// stack's configuration as one `key=value` line.
    #[test]
    fn fallback_server_reports_model_info() {
        let cfg = FallbackConfig {
            seq_len: 32,
            d_model: 16,
            nb: 4,
            depth: 2,
            n_heads: 2,
            d_ff: 32,
            ..Default::default()
        };
        let server = Server::start_fallback(cfg, BatchPolicy::default()).unwrap();
        let r = server.handle.model_info().unwrap();
        let info = r.info.expect("model-info reply carries the description");
        for want in ["backend=fallback", "depth=2", "heads=2", "seq_len=32"] {
            assert!(info.contains(want), "info missing {want}: {info}");
        }
        assert!(r.gen.is_none());
        server.shutdown().unwrap();
    }

    #[test]
    fn missing_artifacts_fall_back() {
        let server = Server::start(
            PathBuf::from("/definitely/not/artifacts"),
            "sstw__sinkhorn_b8".into(),
            None,
            BatchPolicy::default(),
            3,
        )
        .unwrap();
        let resp = server.handle.classify(vec![1, 2, 3, 4]).unwrap();
        assert!(resp.label >= 0);
        server.shutdown().unwrap();
    }

    #[test]
    fn typo_with_artifacts_present_errors_instead_of_falling_back() {
        // a registry.json marks artifacts as present: unknown experiment
        // names must fail loudly rather than serve the toy fallback
        let dir = std::env::temp_dir().join("sinkhorn-svc-typo-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("registry.json"), "{\"experiments\": []}").unwrap();
        let err = Server::start(
            dir,
            "definitely_not_an_experiment".into(),
            None,
            BatchPolicy::default(),
            3,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("not falling back"), "{err:#}");
    }

    #[test]
    fn checkpoint_without_artifacts_errors() {
        let err = Server::start(
            PathBuf::from("/definitely/not/artifacts"),
            "sstw__sinkhorn_b8".into(),
            Some(PathBuf::from("some.ckpt")),
            BatchPolicy::default(),
            3,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("restore a checkpoint"), "{err:#}");
    }
}
