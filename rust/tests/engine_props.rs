//! Property tests for the parallel blocked engine against the naive
//! reference path — these run with no artifacts and no XLA, in every
//! build. The contract under test (DESIGN.md §Engine):
//!
//! 1. fused output == naive output, **bit for bit**, causal and not;
//! 2. parallel output == fused output for any thread count;
//! 3. SortCut with k = nb recovers full attention.

use sinkhorn::sinkhorn::{
    causal_sinkhorn, dense_attention, sinkhorn, sinkhorn_attention, sortcut_attention, Mat,
    SinkhornEngine,
};
use sinkhorn::util::prop::{forall, Gen};
use sinkhorn::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
}

struct Case {
    q: Mat,
    k: Mat,
    v: Mat,
    logits: Mat,
    nb: usize,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Case(ell={}, d={}, nb={})", self.q.rows, self.q.cols, self.nb)
    }
}

fn gen_case(g: &mut Gen) -> Case {
    let nb = 2 + g.usize(0, 5);
    let b = 2 + g.usize(0, 5);
    let d = 4 + g.usize(0, 8);
    let ell = nb * b;
    let mut rng = Rng::new(g.rng.next_u64());
    Case {
        q: rand_mat(&mut rng, ell, d),
        k: rand_mat(&mut rng, ell, d),
        v: rand_mat(&mut rng, ell, d),
        logits: rand_mat(&mut rng, nb, nb),
        nb,
    }
}

#[test]
fn engine_equals_naive_bit_for_bit_across_modes() {
    forall(32, 0xF00D, gen_case, |c| {
        for causal in [false, true] {
            let r = if causal {
                causal_sinkhorn(&c.logits, 6, true)
            } else {
                sinkhorn(&c.logits, 8)
            };
            let naive = sinkhorn_attention(&c.q, &c.k, &c.v, &r, c.nb, causal);
            for threads in [1usize, 2, 5] {
                let eng = SinkhornEngine::new(threads);
                let got = eng.attention(&c.q, &c.k, &c.v, &r, c.nb, causal);
                // bitwise equality — not a tolerance check
                if got != naive {
                    return Err(format!(
                        "threads={threads} causal={causal}: max diff {}",
                        got.max_abs_diff(&naive)
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn engine_sortcut_equals_naive_bit_for_bit() {
    forall(24, 0xF00E, gen_case, |c| {
        let r = sinkhorn(&c.logits, 8);
        for n_cut in 1..=c.nb {
            let naive = sortcut_attention(&c.q, &c.k, &c.v, &r, c.nb, n_cut);
            let got = SinkhornEngine::new(4).sortcut_attention(&c.q, &c.k, &c.v, &r, c.nb, n_cut);
            if got != naive {
                return Err(format!("n_cut={n_cut} diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn sortcut_with_full_cut_equals_full_attention() {
    // paper §3.3: k = nb keeps every sorted block, so SortCut degrades to
    // full (quasi-global) attention. With a hard permutation sort this
    // equals dense attention over the original sequence (softmax is
    // permutation-invariant up to fp summation order).
    forall(
        24,
        0xF00F,
        |g| {
            let nb = 2 + g.usize(0, 5);
            let b = 2 + g.usize(0, 5);
            let d = 4 + g.usize(0, 8);
            let mut rng = Rng::new(g.rng.next_u64());
            let mut perm: Vec<usize> = (0..nb).collect();
            rng.shuffle(&mut perm);
            (
                rand_mat(&mut rng, nb * b, d),
                rand_mat(&mut rng, nb * b, d),
                rand_mat(&mut rng, nb * b, d),
                perm,
                nb,
            )
        },
        |(q, k, v, perm, nb)| {
            let r = Mat::from_fn(*nb, *nb, |i, j| if perm[i] == j { 1.0 } else { 0.0 });
            let cut = SinkhornEngine::auto().sortcut_attention(q, k, v, &r, *nb, *nb);
            let dense = dense_attention(q, k, v, false);
            let diff = cut.max_abs_diff(&dense);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!("sortcut(k=nb) vs dense diff {diff}"))
            }
        },
    );
}

#[test]
fn engine_handles_degenerate_single_block() {
    // nb = 1: the sorted and local terms both see the whole sequence
    let mut rng = Rng::new(42);
    let (q, k, v) = (rand_mat(&mut rng, 6, 4), rand_mat(&mut rng, 6, 4), rand_mat(&mut rng, 6, 4));
    let r = Mat::eye(1);
    let naive = sinkhorn_attention(&q, &k, &v, &r, 1, false);
    let got = SinkhornEngine::auto().attention(&q, &k, &v, &r, 1, false);
    assert_eq!(naive, got);
}
