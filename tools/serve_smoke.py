#!/usr/bin/env python3
"""End-to-end TCP smoke test of the serving stack (`make serve-smoke`,
wired into `make ci`): spawn the pure-Rust fallback server on an
ephemeral port, drive the line protocol over a real socket — classify,
a *streamed* generation (`tok <i> <id>` lines then the `tokens=`
summary), the `model` info verb, and the stable error replies — and
assert every reply shape. This is the one gate that exercises the
process boundary: CLI flag parsing, the TCP frontend, the continuous
scheduler, and the streaming protocol together (DESIGN.md §Scheduler).

Needs a Rust toolchain (it runs the built `sinkhorn serve` binary); the
Makefile target skips loudly when `cargo` is absent, like fmt-check.

Usage: python3 tools/serve_smoke.py
Env: CARGO (default "cargo").
Exit code 0 on success, 1 on any failed assertion.
"""
import os
import re
import socket
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CARGO = os.environ.get("CARGO", "cargo")
ADDR_RE = re.compile(r"tcp frontend listening on 127\.0\.0\.1:(\d+)")


def fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def main() -> int:
    cmd = [
        CARGO, "run", "--release", "--manifest-path", str(ROOT / "rust" / "Cargo.toml"),
        "--", "serve", "--fallback", "--port", "0", "--wait",
        "--seq-len", "32", "--max-sessions", "4",
    ]
    print("+ " + " ".join(cmd))
    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, cwd=ROOT
    )
    port = None
    deadline = time.time() + 600  # first run may compile
    try:
        while time.time() < deadline:
            line = proc.stdout.readline()
            if not line:
                fail(f"server exited early (rc={proc.poll()})")
            sys.stdout.write(f"[server] {line}")
            m = ADDR_RE.search(line)
            if m:
                port = int(m.group(1))
                break
        if port is None:
            fail("server never announced its TCP port")

        sock = socket.create_connection(("127.0.0.1", port), timeout=30)
        f = sock.makefile("rw", encoding="utf-8", newline="\n")

        def send(line: str) -> None:
            f.write(line + "\n")
            f.flush()

        def recv() -> str:
            reply = f.readline().rstrip("\n")
            print(f"[client] {reply}")
            return reply

        # classify: one stable label= line
        send("4 8 15 16 23 42")
        reply = recv()
        if not reply.startswith("label="):
            fail(f"classify reply: {reply!r}")

        # streamed generation: exactly 4 `tok <i> <id>` lines (indices in
        # order), then the `tokens=` summary whose ids match the stream
        send("gen 4 1 2 3")
        tok_ids = []
        while True:
            reply = recv()
            if reply.startswith("tok "):
                idx, tid = reply.split()[1:3]
                if int(idx) != len(tok_ids):
                    fail(f"tok indices out of order: {reply!r}")
                tok_ids.append(int(tid))
            else:
                break
        if not reply.startswith("tokens="):
            fail(f"gen summary reply: {reply!r}")
        summary_ids = [int(t) for t in reply.split()[0][len("tokens="):].split(",") if t]
        if len(tok_ids) != 4 or tok_ids != summary_ids:
            fail(f"streamed ids {tok_ids} != summary ids {summary_ids}")

        # model info: the served configuration as one key=value line
        send("model")
        reply = recv()
        if "backend=fallback" not in reply or "seq_len=32" not in reply:
            fail(f"model reply: {reply!r}")

        # stable errors: unknown verb, zero-budget gen
        send("frobnicate 1 2")
        if recv() != "error=unknown verb 'frobnicate'":
            fail("unknown-verb reply drifted")
        send("gen 0 1")
        if recv() != "error=gen count must be positive":
            fail("zero-count reply drifted")

        sock.close()
        print("serve-smoke: OK (classify, streamed gen, model, stable errors)")
        return 0
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
