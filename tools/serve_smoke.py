#!/usr/bin/env python3
"""End-to-end TCP smoke test of the serving stack (`make serve-smoke`,
wired into `make ci`): spawn the pure-Rust fallback server on an
ephemeral port, drive the line protocol over a real socket — classify,
a *streamed* generation (`tok <i> <id>` lines then the `tokens=`
summary), the `model` info verb, and the stable error replies — and
assert every reply shape. This is the one gate that exercises the
process boundary: CLI flag parsing, the TCP frontend, the continuous
scheduler, and the streaming protocol together (DESIGN.md §Scheduler).

A second phase re-spawns the server at capacity one (`--max-sessions 1
--queue-depth 0`) and drives it *over* admission: while connection A
streams a long generation, connection B's request must get the stable
`busy=` line back on a connection that stays usable, and the same
request retried after A retires must succeed — the admission overflow
and slot-reuse paths of DESIGN.md §Scheduler observed from outside the
process.

A fourth phase drives the HTTP/JSON gateway (DESIGN.md §Gateway) over a
raw socket on a capacity-one server: a typed classify POST, the
`/v1/schema` route listing, stable JSON error bodies for a bad route and
a zero-budget generate, an SSE generate abandoned after its first `tok`
event (the client vanishes; the server must cancel the generation and
free the only slot — proven by the identical retry succeeding), and the
`/v1/shutdown` route, after which the `--wait` process must exit 0.

A third phase (`--chaos`, wired as `make chaos-smoke`) exercises the
fault-tolerance paths of DESIGN.md §Faults from outside the process: a
client killed mid-stream must not disturb a concurrent session, the
`shutdown` verb must reply `ok=draining`, refuse follow-up work with a
stable error, resolve the still-streaming connection (summary or
`error=server shutting down`), and the `--wait` process must then exit
0 on its own — the graceful-drain contract observed end to end.

Needs a Rust toolchain (it runs the built `sinkhorn serve` binary); the
Makefile target skips loudly when `cargo` is absent, like fmt-check.

Usage: python3 tools/serve_smoke.py [--chaos]
  (no flag: phases 1+2+4; --chaos: the chaos phase only)
Env: CARGO (default "cargo").
Exit code 0 on success, 1 on any failed assertion.
"""
import json
import os
import re
import socket
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CARGO = os.environ.get("CARGO", "cargo")
ADDR_RE = re.compile(r"tcp frontend listening on 127\.0\.0\.1:(\d+)")
HTTP_ADDR_RE = re.compile(r"http frontend listening on 127\.0\.0\.1:(\d+)")
BUSY_LINE = "busy=generation queue full"


def fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def spawn_server(extra_flags, want_http=False):
    """Start `serve --fallback` on an ephemeral port; return
    (proc, tcp_port, http_port). `http_port` is None unless `want_http`
    (pass `--http-port 0` in `extra_flags` to get one)."""
    cmd = [
        CARGO, "run", "--release", "--manifest-path", str(ROOT / "rust" / "Cargo.toml"),
        "--", "serve", "--fallback", "--port", "0", "--wait",
    ] + extra_flags
    print("+ " + " ".join(cmd))
    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, cwd=ROOT
    )
    deadline = time.time() + 600  # first run may compile
    ports = {}
    while time.time() < deadline:
        line = proc.stdout.readline()
        if not line:
            fail(f"server exited early (rc={proc.poll()})")
        sys.stdout.write(f"[server] {line}")
        m = ADDR_RE.search(line)
        if m:
            ports["tcp"] = int(m.group(1))
        m = HTTP_ADDR_RE.search(line)
        if m:
            ports["http"] = int(m.group(1))
        if "tcp" in ports and ("http" in ports or not want_http):
            return proc, ports["tcp"], ports.get("http")
    fail("server never announced its listening port(s)")


def stop_server(proc) -> None:
    proc.terminate()
    try:
        proc.wait(timeout=10)
    except subprocess.TimeoutExpired:
        proc.kill()


class Conn:
    """One line-protocol client connection with logged traffic."""

    def __init__(self, port: int, tag: str):
        self.sock = socket.create_connection(("127.0.0.1", port), timeout=60)
        self.f = self.sock.makefile("rw", encoding="utf-8", newline="\n")
        self.tag = tag

    def send(self, line: str) -> None:
        self.f.write(line + "\n")
        self.f.flush()

    def recv(self) -> str:
        reply = self.f.readline().rstrip("\n")
        print(f"[{self.tag}] {reply}")
        return reply

    def drain_gen(self, seed=None):
        """Read a streamed generation: `tok` lines then the summary line.
        `seed` carries token ids already consumed off this stream (the
        index check continues from them). Returns (ids, summary)."""
        tok_ids = list(seed or [])
        while True:
            reply = self.recv()
            if reply.startswith("tok "):
                idx, tid = reply.split()[1:3]
                if int(idx) != len(tok_ids):
                    fail(f"{self.tag}: tok indices out of order: {reply!r}")
                tok_ids.append(int(tid))
            else:
                return tok_ids, reply

    def close(self) -> None:
        self.sock.close()


def check_gen_summary(tag: str, tok_ids, summary: str, want_n: int) -> None:
    if not summary.startswith("tokens="):
        fail(f"{tag}: gen summary reply: {summary!r}")
    summary_ids = [int(t) for t in summary.split()[0][len("tokens="):].split(",") if t]
    if len(tok_ids) != want_n or tok_ids != summary_ids:
        fail(f"{tag}: streamed ids {tok_ids} != summary ids {summary_ids} (want {want_n})")


def phase_protocol() -> None:
    """Classify, streamed gen, model info, and the stable error replies."""
    proc, port, _ = spawn_server(["--seq-len", "32", "--max-sessions", "4"])
    try:
        c = Conn(port, "client")

        # classify: one stable label= line
        c.send("4 8 15 16 23 42")
        reply = c.recv()
        if not reply.startswith("label="):
            fail(f"classify reply: {reply!r}")

        # streamed generation: exactly 4 `tok <i> <id>` lines (indices in
        # order), then the `tokens=` summary whose ids match the stream
        c.send("gen 4 1 2 3")
        tok_ids, reply = c.drain_gen()
        check_gen_summary("client", tok_ids, reply, 4)

        # model info: the served configuration as one key=value line
        c.send("model")
        reply = c.recv()
        if "backend=fallback" not in reply or "seq_len=32" not in reply:
            fail(f"model reply: {reply!r}")

        # stable errors: unknown verb, zero-budget gen
        c.send("frobnicate 1 2")
        if c.recv() != "error=unknown verb 'frobnicate'":
            fail("unknown-verb reply drifted")
        c.send("gen 0 1")
        if c.recv() != "error=gen count must be positive":
            fail("zero-count reply drifted")

        c.close()
        print("serve-smoke phase 1: OK (classify, streamed gen, model, stable errors)")
    finally:
        stop_server(proc)


def phase_over_admission() -> None:
    """Drive the server past its admission bound: a second generation
    must get the stable busy= line while the single slot is held, and the
    identical retry must succeed once the slot retires."""
    # capacity one, no wait queue; the long seq_len gives conn A a
    # generation that outlives the busy-probe round trip by a wide margin
    proc, port, _ = spawn_server(
        ["--seq-len", "512", "--max-sessions", "1", "--queue-depth", "0"]
    )
    try:
        a = Conn(port, "conn A")
        b = Conn(port, "conn B")

        # conn A takes the only slot; its first tok line proves it was
        # admitted and is streaming
        a.send("gen 400 1 2 3")
        first = a.recv()
        if not first.startswith("tok 0 "):
            fail(f"over-admission: conn A first reply {first!r}, want 'tok 0 <id>'")

        # conn B overflows `slots + queue_depth` and must get the stable
        # busy line — and nothing else — without losing its connection
        b.send("gen 4 9 8 7")
        reply = b.recv()
        if reply != BUSY_LINE:
            fail(f"over-admission: want {BUSY_LINE!r}, got {reply!r}")

        # drain A to its summary; retiring frees the slot
        tok_ids, reply = a.drain_gen(seed=[int(first.split()[2])])
        check_gen_summary("conn A", tok_ids, reply, 400)

        # same request, same connection, after retirement: admitted
        b.send("gen 4 9 8 7")
        tok_ids, reply = b.drain_gen()
        check_gen_summary("conn B", tok_ids, reply, 4)

        a.close()
        b.close()
        print("serve-smoke phase 2: OK (busy= under over-admission, retry after retirement)")
    finally:
        stop_server(proc)


def phase_chaos() -> None:
    """Kill a client mid-stream, then drive a graceful drain shutdown —
    the fault-tolerance contract (DESIGN.md §Faults) from outside the
    process: survivors keep serving, every connection resolves with a
    stable line, and the drained `--wait` process exits 0 by itself."""
    # the long seq_len keeps chaos-victim generations in flight while we
    # act; a small drain window keeps the final wait fast either way
    proc, port, _ = spawn_server(
        ["--seq-len", "512", "--max-sessions", "4", "--drain-ms", "500"]
    )
    try:
        # conn A: stream a long generation, read a few tokens, vanish.
        # The server's next write fails, the session is cancelled, and —
        # the actual assertion — nobody else notices.
        a = Conn(port, "conn A")
        a.send("gen 400 1 2 3")
        for _ in range(3):
            reply = a.recv()
            if not reply.startswith("tok "):
                fail(f"chaos: conn A expected tok lines, got {reply!r}")
        a.close()
        print("[chaos] conn A killed mid-stream")

        # conn B: a full request right through the wreckage
        b = Conn(port, "conn B")
        b.send("gen 4 9 8 7")
        tok_ids, reply = b.drain_gen()
        check_gen_summary("conn B", tok_ids, reply, 4)
        b.close()

        # conn C: still streaming when the drain begins
        c = Conn(port, "conn C")
        c.send("gen 400 5 5 5")
        first = c.recv()
        if not first.startswith("tok 0 "):
            fail(f"chaos: conn C first reply {first!r}, want 'tok 0 <id>'")

        # conn D: begin the graceful drain, then probe the intake refusal
        d = Conn(port, "conn D")
        d.send("shutdown")
        reply = d.recv()
        if reply != "ok=draining":
            fail(f"chaos: shutdown reply {reply!r}, want 'ok=draining'")
        d.send("gen 4 1 2 3")
        reply = d.recv()
        if not (reply == "error=server shutting down" or reply.startswith("error=server ")):
            fail(f"chaos: post-drain request got {reply!r}, want a stable error")
        d.close()

        # conn C resolves either way: finished inside the drain window
        # (tokens= summary) or aborted with the stable shutdown error
        tok_ids, reply = c.drain_gen(seed=[int(first.split()[2])])
        if reply.startswith("tokens="):
            check_gen_summary("conn C", tok_ids, reply, 400)
        elif reply != "error=server shutting down":
            fail(f"chaos: conn C resolution {reply!r}")
        c.close()

        # the drained --wait process exits cleanly on its own
        try:
            rc = proc.wait(timeout=60)
        except subprocess.TimeoutExpired:
            fail("chaos: drained server never exited")
        for line in proc.stdout:
            sys.stdout.write(f"[server] {line}")
        if rc != 0:
            fail(f"chaos: drained server exited rc={rc}")
        print("serve-smoke phase 3: OK (mid-stream kill isolated, drain shutdown clean)")
    finally:
        stop_server(proc)


def http_roundtrip(port: int, method: str, path: str, body=None, timeout=120):
    """One raw-socket HTTP exchange with `Connection: close`; returns
    (status, headers, body bytes) with any chunked framing decoded."""
    s = socket.create_connection(("127.0.0.1", port), timeout=timeout)
    payload = (body or "").encode()
    req = f"{method} {path} HTTP/1.1\r\nConnection: close\r\n"
    if body is not None:
        req += f"Content-Type: application/json\r\nContent-Length: {len(payload)}\r\n"
    req += "\r\n"
    s.sendall(req.encode() + payload)
    raw = b""
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break
        raw += chunk
    s.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    lines = head.decode().split("\r\n")
    status = int(lines[0].split()[1])
    headers = {}
    for h in lines[1:]:
        name, _, value = h.partition(":")
        headers[name.strip().lower()] = value.strip()
    if headers.get("transfer-encoding") == "chunked":
        decoded = b""
        while rest:
            szline, _, rest = rest.partition(b"\r\n")
            n = int(szline.split(b";")[0], 16)
            if n == 0:
                break
            decoded += rest[:n]
            rest = rest[n + 2:]
        return status, headers, decoded
    return status, headers, rest


def sse_events(body: bytes):
    """Split a chunk-decoded SSE body into (event, parsed-json) pairs."""
    out = []
    for block in body.decode().split("\n\n"):
        if not block:
            continue
        event, data = "", ""
        for line in block.splitlines():
            if line.startswith("event: "):
                event = line[len("event: "):]
            elif line.startswith("data: "):
                data = line[len("data: "):]
        out.append((event, json.loads(data)))
    return out


def phase_http() -> None:
    """Drive the HTTP/JSON gateway over a raw socket: typed routes,
    stable JSON errors, an abandoned SSE stream that must free the only
    admission slot (the PR cancel path), and route-driven shutdown."""
    proc, _tcp, port = spawn_server(
        ["--seq-len", "512", "--max-sessions", "1", "--drain-ms", "500", "--http-port", "0"],
        want_http=True,
    )
    try:
        # classify: typed request in, typed response out
        status, _, body = http_roundtrip(
            port, "POST", "/v1/classify", json.dumps({"tokens": [4, 8, 15, 16, 23, 42]})
        )
        if status != 200 or "label" not in json.loads(body):
            fail(f"http classify: status {status}, body {body!r}")
        print("[http] classify OK")

        # schema: the published table matches the routes this phase uses
        status, _, body = http_roundtrip(port, "GET", "/v1/schema")
        routes = {(r["method"], r["path"]) for r in json.loads(body)["routes"]}
        need = {("POST", "/v1/classify"), ("POST", "/v1/generate"), ("GET", "/v1/model"),
                ("GET", "/v1/schema"), ("POST", "/v1/shutdown")}
        if status != 200 or not need <= routes:
            fail(f"http schema: status {status}, routes {routes}")
        print("[http] schema OK")

        # stable JSON error bodies: bad route, zero-budget generate
        status, _, body = http_roundtrip(port, "GET", "/v1/frobnicate")
        if status != 404 or json.loads(body)["error"] != "no such route":
            fail(f"http 404: status {status}, body {body!r}")
        status, _, body = http_roundtrip(
            port, "POST", "/v1/generate", json.dumps({"max_new": 0, "tokens": [1]})
        )
        if status != 400 or json.loads(body)["error"] != "gen count must be positive":
            fail(f"http zero-budget: status {status}, body {body!r}")
        print("[http] stable error bodies OK")

        # SSE generate, abandoned: read the first tok event, vanish. The
        # server's next chunk write fails, the generation is cancelled,
        # and — the assertion — the *only* slot frees for the retry.
        s = socket.create_connection(("127.0.0.1", port), timeout=120)
        greq = json.dumps({"max_new": 400, "tokens": [1, 2, 3]})
        s.sendall(
            (
                f"POST /v1/generate HTTP/1.1\r\nContent-Type: application/json\r\n"
                f"Content-Length: {len(greq)}\r\n\r\n{greq}"
            ).encode()
        )
        seen = b""
        while b"event: tok" not in seen:
            chunk = s.recv(4096)
            if not chunk:
                fail("http sse: stream closed before the first tok event")
            seen += chunk
        if not seen.startswith(b"HTTP/1.1 200"):
            fail(f"http sse: {seen[:60]!r}")
        s.close()
        print("[http] sse stream abandoned mid-flight")

        # identical retry on the capacity-one server: only passes if the
        # abandoned session released its slot and reservation
        status, _, body = http_roundtrip(
            port, "POST", "/v1/generate", json.dumps({"max_new": 4, "tokens": [1, 2, 3]})
        )
        events = sse_events(body)
        toks = [e[1]["id"] for e in events if e[0] == "tok"]
        done = [e[1] for e in events if e[0] == "done"]
        if status != 200 or not done or toks != done[0]["tokens"] or len(toks) != 4:
            fail(f"http retry after abandon: status {status}, events {events}")
        print("[http] retry after abandon OK (slot freed)")

        # shutdown via the route; the --wait process drains and exits 0
        status, _, body = http_roundtrip(port, "POST", "/v1/shutdown")
        if status != 200 or json.loads(body).get("ok") != "draining":
            fail(f"http shutdown: status {status}, body {body!r}")
        try:
            rc = proc.wait(timeout=60)
        except subprocess.TimeoutExpired:
            fail("http: drained server never exited")
        for line in proc.stdout:
            sys.stdout.write(f"[server] {line}")
        if rc != 0:
            fail(f"http: drained server exited rc={rc}")
        print("serve-smoke phase 4: OK (http routes, sse cancel path, shutdown)")
    finally:
        stop_server(proc)


def main() -> int:
    if "--chaos" in sys.argv[1:]:
        phase_chaos()
    else:
        phase_protocol()
        phase_over_admission()
        phase_http()
    print("serve-smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
