//! Small dense row-major f32 matrices for the pure-Rust reference
//! implementation of Sparse Sinkhorn Attention (no BLAS offline; sizes
//! here are tiny — nb x nb sort matrices and b x d tiles), plus the
//! zero-copy strided views ([`MatView`]/[`MatViewMut`]) and write-into
//! kernels that back the allocation-free blocked engine
//! (`sinkhorn::engine`, DESIGN.md §Engine). The views follow the same
//! row-major shape+stride conventions as `runtime::tensor::HostTensor`
//! (which bridges into them via `HostTensor::mat_view`).
//!
//! **Bit-exactness contract:** every `*_into` kernel performs the same
//! floating-point operations in the same order as the corresponding
//! owning `Mat` method (`matmul`, `matmul_t` + `scale`, `softmax_rows`),
//! so the fused engine reproduces the naive reference path bit for bit.
//! The property tests in `sinkhorn::engine` pin this down; keep the loop
//! orders in sync when editing either side.

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// C = A @ B.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// C = A @ B^T.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t dims");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            for j in 0..other.rows {
                let mut acc = 0.0;
                for k in 0..self.cols {
                    acc += self[(i, k)] * other[(j, k)];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row-wise softmax in place.
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let r = self.row_mut(i);
            let m = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in r.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            for x in r.iter_mut() {
                *x /= sum;
            }
        }
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

// --- zero-copy strided views ------------------------------------------------

/// Immutable view of a row-major `(rows, cols)` region inside a shared
/// buffer; `row_stride >= cols` lets a view select a column band (e.g. the
/// sorted half of a `(b, 2b)` logits tile).
#[derive(Debug, Clone, Copy)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub row_stride: usize,
    data: &'a [f32],
}

impl<'a> MatView<'a> {
    pub fn new(data: &'a [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(cols <= row_stride, "cols {cols} > row_stride {row_stride}");
        assert!(
            rows == 0 || (rows - 1) * row_stride + cols <= data.len(),
            "view {rows}x{cols} (stride {row_stride}) exceeds buffer of {}",
            data.len()
        );
        MatView { rows, cols, row_stride, data }
    }

    /// Contiguous view over a whole buffer.
    pub fn contiguous(data: &'a [f32], rows: usize, cols: usize) -> Self {
        Self::new(data, rows, cols, cols)
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.row_stride + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }

    /// Materialize into an owning `Mat` (test/debug helper).
    pub fn to_mat(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self.at(i, j))
    }
}

/// Mutable strided view (same layout rules as [`MatView`]).
#[derive(Debug)]
pub struct MatViewMut<'a> {
    pub rows: usize,
    pub cols: usize,
    pub row_stride: usize,
    data: &'a mut [f32],
}

impl<'a> MatViewMut<'a> {
    pub fn new(data: &'a mut [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(cols <= row_stride, "cols {cols} > row_stride {row_stride}");
        assert!(
            rows == 0 || (rows - 1) * row_stride + cols <= data.len(),
            "view {rows}x{cols} (stride {row_stride}) exceeds buffer of {}",
            data.len()
        );
        MatViewMut { rows, cols, row_stride, data }
    }

    pub fn contiguous(data: &'a mut [f32], rows: usize, cols: usize) -> Self {
        Self::new(data, rows, cols, cols)
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.row_stride + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, x: f32) {
        self.data[i * self.row_stride + j] = x;
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.row_stride..i * self.row_stride + self.cols]
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, row_stride: self.row_stride, data: &*self.data }
    }

    pub fn fill(&mut self, x: f32) {
        for i in 0..self.rows {
            self.row_mut(i).fill(x);
        }
    }
}

impl Mat {
    pub fn view(&self) -> MatView<'_> {
        MatView::contiguous(&self.data, self.rows, self.cols)
    }

    pub fn view_mut(&mut self) -> MatViewMut<'_> {
        MatViewMut::contiguous(&mut self.data, self.rows, self.cols)
    }

    /// Zero-copy view of a contiguous row range `[r0, r0 + rows)`.
    pub fn row_block(&self, r0: usize, rows: usize) -> MatView<'_> {
        assert!(r0 + rows <= self.rows, "row block {r0}+{rows} > {}", self.rows);
        MatView::contiguous(&self.data[r0 * self.cols..(r0 + rows) * self.cols], rows, self.cols)
    }
}

// --- write-into kernels (bit-exact mirrors of the Mat methods) --------------

/// `out = (a @ b^T) * scale`, written into a preallocated view.
///
/// Mirrors `a.matmul_t(b)` followed by `scale()`: identical accumulation
/// order (`k` innermost), scaling applied to the finished dot product —
/// multiplying after the sum equals scaling the stored value, so results
/// are bit-identical to the two-pass reference.
pub fn matmul_t_scaled_into(a: &MatView, b: &MatView, scale: f32, out: &mut MatViewMut) {
    assert_eq!(a.cols, b.cols, "matmul_t dims");
    assert_eq!((out.rows, out.cols), (a.rows, b.rows), "out dims");
    for i in 0..a.rows {
        let ar = a.row(i);
        for j in 0..b.rows {
            let br = b.row(j);
            let mut acc = 0.0f32;
            for k in 0..a.cols {
                acc += ar[k] * br[k];
            }
            out.set(i, j, acc * scale);
        }
    }
}

/// `out = probs @ v` (zero-initializing `out` first), same `i-k-j` loop
/// order and zero-weight skip as `Mat::matmul` — bit-identical results.
pub fn matmul_into(probs: &MatView, v: &MatView, out: &mut MatViewMut) {
    assert_eq!(probs.cols, v.rows, "matmul dims");
    assert_eq!((out.rows, out.cols), (probs.rows, v.cols), "out dims");
    out.fill(0.0);
    for i in 0..probs.rows {
        for k in 0..probs.cols {
            let a = probs.at(i, k);
            if a == 0.0 {
                continue;
            }
            let vr = v.row(k);
            let or = out.row_mut(i);
            for j in 0..v.cols {
                or[j] += a * vr[j];
            }
        }
    }
}

/// `out += t` elementwise (the reference path's `Mat::add`).
pub fn add_assign(out: &mut MatViewMut, t: &MatView) {
    assert_eq!((out.rows, out.cols), (t.rows, t.cols), "add dims");
    for i in 0..out.rows {
        let tr = t.row(i);
        let or = out.row_mut(i);
        for (o, x) in or.iter_mut().zip(tr) {
            *o += x;
        }
    }
}

/// Row-wise softmax in place over the view's full width — the same
/// max-shift/exp/normalize sequence as `Mat::softmax_rows`.
pub fn softmax_rows_inplace(x: &mut MatViewMut) {
    for i in 0..x.rows {
        let r = x.row_mut(i);
        let m = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in r.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in r.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(a.matmul(&Mat::eye(3)), a);
        assert_eq!(Mat::eye(3).matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches() {
        let a = Mat::from_fn(2, 4, |i, j| (i + j) as f32);
        let b = Mat::from_fn(3, 4, |i, j| (i * j) as f32 + 1.0);
        let bt = Mat::from_fn(4, 3, |i, j| b[(j, i)]);
        assert_eq!(a.matmul_t(&b), a.matmul(&bt));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut a = Mat::from_fn(4, 5, |i, j| (i as f32) - (j as f32) * 0.3);
        a.softmax_rows();
        for i in 0..4 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    fn demo(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = crate::util::rng::Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32)
    }

    #[test]
    fn views_select_blocks_and_bands() {
        let m = demo(6, 4, 1);
        // contiguous row block
        let blk = m.row_block(2, 2);
        assert_eq!(blk.to_mat(), Mat::from_fn(2, 4, |i, j| m[(i + 2, j)]));
        // strided column band: right half of each row
        let band = MatView::new(&m.data[2..], 6, 2, 4);
        assert_eq!(band.to_mat(), Mat::from_fn(6, 2, |i, j| m[(i, j + 2)]));
        assert_eq!(m.view().to_mat(), m);
    }

    #[test]
    fn matmul_t_scaled_into_is_bit_exact() {
        let a = demo(3, 5, 2);
        let b = demo(4, 5, 3);
        let mut want = a.matmul_t(&b);
        want.scale(0.25);
        let mut out = Mat::zeros(3, 4);
        matmul_t_scaled_into(&a.view(), &b.view(), 0.25, &mut out.view_mut());
        assert_eq!(out, want); // bitwise: same op order by construction
    }

    #[test]
    fn matmul_into_is_bit_exact() {
        let a = demo(3, 4, 4);
        let b = demo(4, 6, 5);
        let want = a.matmul(&b);
        let mut out = Mat::from_fn(3, 6, |_, _| 9.9); // pre-dirty: must be zeroed
        matmul_into(&a.view(), &b.view(), &mut out.view_mut());
        assert_eq!(out, want);
    }

    #[test]
    fn softmax_inplace_matches_mat() {
        let mut a = demo(4, 7, 6);
        let mut b = a.clone();
        a.softmax_rows();
        softmax_rows_inplace(&mut b.view_mut());
        assert_eq!(a, b);
    }

    #[test]
    fn add_assign_matches_add() {
        let mut a = demo(3, 3, 7);
        let t = demo(3, 3, 8);
        let mut want = a.clone();
        want.add(&t);
        add_assign(&mut a.view_mut(), &t.view());
        assert_eq!(a, want);
    }

    #[test]
    fn strided_write_only_touches_band() {
        // write a (2,2) product into the left band of a (2,5)-strided buffer
        let a = Mat::eye(2);
        let b = demo(2, 3, 9);
        let mut buf = vec![7.0f32; 2 * 5];
        {
            let mut out = MatViewMut::new(&mut buf, 2, 3, 5);
            matmul_into(&a.view(), &b.view(), &mut out);
        }
        for i in 0..2 {
            assert_eq!(&buf[i * 5..i * 5 + 3], b.row(i));
            assert_eq!(&buf[i * 5 + 3..i * 5 + 5], &[7.0, 7.0]); // untouched
        }
    }
}
