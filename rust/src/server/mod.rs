//! Batched inference serving (the L3 "router" role): client threads submit
//! requests — classify (token ids → label) or generate (prompt → greedily
//! decoded ids, DESIGN.md §Decode, optionally streamed token by token); a
//! single executor thread owning the execution backend serves them. The
//! pure-Rust backend ([`fallback`] — works on any machine, serves every
//! verb) runs a token-level **continuous-batching scheduler** by default:
//! a session table advances all in-flight generations one token per tick,
//! with memory-budgeted admission control (DESIGN.md §Scheduler). The
//! PJRT runtime over compiled artifacts (classify only) and the
//! [`batch::ExecMode::RequestBatch`] escape hatch run the legacy
//! wave executor instead. Two frontends serve the same handle: the TCP
//! line protocol ([`tcp`], `rust/README.md`) and the HTTP/JSON gateway
//! with SSE token streaming ([`http`] + [`json`], DESIGN.md §Gateway).
//!
//! The stack is fault-tolerant by construction (DESIGN.md §Faults):
//! generations carry deadlines and cancellation tokens, slow clients are
//! isolated behind bounded outboxes, per-session work is panic-contained,
//! shutdown drains gracefully, and the [`faults`] module injects
//! deterministic failure schedules through all of it for the chaos tests.

pub mod batch;
pub mod fallback;
pub mod faults;
pub mod http;
pub mod json;
pub mod service;
pub mod tcp;

pub use batch::{gather, BatchPolicy, ExecMode};
pub use fallback::{FallbackConfig, FallbackModel, GenSession, StepOutcome};
pub use faults::{FaultPlan, FaultSpec, SockFault};
pub use http::{HttpConfig, HttpFrontend};
pub use service::{
    CancelToken, GenOptions, Response, Server, ServerHandle, StreamingGen, TokenEvent, BUSY_MSG,
    CANCELLED_MSG, DEADLINE_MSG, SHUTDOWN_MSG, STALL_MSG,
};
pub use tcp::{TcpConfig, TcpFrontend, IDLE_MSG};
