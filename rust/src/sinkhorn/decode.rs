//! Incremental autoregressive decoding for Sparse Sinkhorn Attention
//! (DESIGN.md §Decode).
//!
//! The batch paths ([`super::attention`], [`super::engine`]) recompute the
//! whole sequence's attention on every call — O(ℓ·b·d) per token if a
//! server replayed them once per generated token. This module is the
//! serving decode path: a per-sequence [`DecodeState`] caches everything
//! that survives from step to step, so producing one more token costs
//! O(b·d):
//!
//! * **K/V cache** — the new token's projected key/value rows are appended
//!   into preallocated block-aligned buffers; nothing earlier is touched.
//! * **Cached causal Sinkhorn state** — the balanced sort matrix `R` is
//!   recomputed (Causal Sinkhorn Balancing, [`causal_sinkhorn`] with
//!   `strict = true`) only when a block boundary fills. This is sound
//!   because strict-causal balancing is *prefix-consistent*: `R[i, j]`
//!   depends only on logits rows `<= i`, so the `(m, m)` balance of the
//!   first `m` blocks agrees with the top-left of any larger balance
//!   (pinned by `balance.rs::causal_prefix_consistent` and the float32
//!   simulation in EXPERIMENTS.md). Between boundaries the cached rows are
//!   reused as-is.
//! * **Cached sorted K/V** — the gathered sorted blocks the current token
//!   attends to are materialized once per boundary ([`gather_block_into`]
//!   over the complete blocks) and then reused for every token of the
//!   block. Strictness guarantees the gather never reads the in-progress
//!   block (its weight is exactly zero).
//! * **Streaming-softmax carry** — each step runs the engine's
//!   `stream_segment` twice (sorted segment, then the local causal
//!   window), carrying the running max/denominator between them in a
//!   caller-provided `StreamState`; the `(1, keys)` logits are never
//!   materialized.
//!
//! **SortCut decoding** (paper §3.3): with `n_cut = Some(c)` every token
//! attends to `[first c sorted blocks | local causal window]` instead of
//! its own block's sorted row. Prefix-consistency makes the cut cache
//! *append-only*: once row `j < c` of `R` exists it never changes, so each
//! boundary only gathers the newly live rows — and once the cut is
//! complete, later boundaries skip rebalancing altogether (no balanced
//! row would ever be read again).
//!
//! **Contract** (`tests/decode_props.rs`): every step's output matches the
//! naive full-prefix oracle [`causal_decode_attention`] within
//! [`ENGINE_TOL`](super::engine::ENGINE_TOL) — including steps that cross
//! a block boundary and every `n_cut` — and a batch of sequences decoded
//! through [`SinkhornEngine::decode_step_into`] is bit-identical for any
//! thread count. Memory is accounted analytically by
//! [`memory::decode_state_bytes`] and asserted against
//! [`DecodeState::f32_elems`].
//!
//! [`causal_sinkhorn`]: super::balance::causal_sinkhorn
//! [`causal_decode_attention`]: super::attention::causal_decode_attention
//! [`SinkhornEngine::decode_step_into`]: super::engine::SinkhornEngine::decode_step_into
//! [`memory::decode_state_bytes`]: super::memory::decode_state_bytes

use super::balance::causal_sinkhorn;
use super::engine::{gather_block_into, normalize_rows, BlockedView, StreamState};
use super::matrix::{Mat, MatView, MatViewMut};

/// Row-support threshold below which a balanced sort row is treated as
/// empty and its sorted term masked — the same cutoff the batch paths use.
const SUPPORT_EPS: f32 = 1e-6;

/// Per-sequence incremental decode state (DESIGN.md §Decode): the
/// block-aligned K/V cache, the cached strict-causal balanced sort matrix,
/// and the gathered sorted K/V the current tokens attend to. Everything is
/// preallocated at construction; a step allocates only when a block
/// boundary rebalances the (tiny) sort matrix.
pub struct DecodeState {
    /// rows per block
    b: usize,
    /// model dim
    d: usize,
    /// capacity in blocks (sequence capacity = `nb_cap * b` tokens)
    nb_cap: usize,
    /// Sinkhorn balance iterations per rebalance
    n_iters: usize,
    /// `Some(c)`: SortCut decoding over the first `c` sorted blocks;
    /// `None`: full causal decoding over the token's own sorted row
    n_cut: Option<usize>,
    /// appended keys, block-aligned: token `t`'s row lives at `t * d`
    k: Vec<f32>,
    /// appended values, same layout
    v: Vec<f32>,
    /// tokens appended so far
    len: usize,
    /// cached balanced sort matrix: top-left `(balanced, balanced)` of this
    /// preallocated `(nb_cap, nb_cap)` buffer holds
    /// `causal_sinkhorn(logits[..balanced, ..balanced], n_iters, strict)`
    r: Mat,
    /// blocks covered by the cached balance (0 before the first step)
    balanced: usize,
    /// gathered sorted keys the current tokens attend to: `(b, d)` in full
    /// mode, up to `(n_cut * b, d)` in SortCut mode
    sk: Vec<f32>,
    /// gathered sorted values, same layout
    sv: Vec<f32>,
    /// valid key rows in `sk`/`sv`
    sorted_rows: usize,
    /// SortCut: balanced rows already consumed into the cut cache
    /// (append-only — prefix-consistency keeps earlier rows stable)
    cut_rows: usize,
}

impl DecodeState {
    /// Fresh state for a sequence of up to `nb_cap * b` tokens.
    pub fn new(b: usize, d: usize, nb_cap: usize, n_iters: usize, n_cut: Option<usize>) -> Self {
        assert!(b > 0 && d > 0 && nb_cap > 0, "b, d, nb_cap must be positive");
        if let Some(c) = n_cut {
            assert!((1..=nb_cap).contains(&c), "n_cut must be in 1..=nb_cap, got {c}");
        }
        let cache_blocks = n_cut.unwrap_or(1);
        DecodeState {
            b,
            d,
            nb_cap,
            n_iters,
            n_cut,
            k: vec![0.0; nb_cap * b * d],
            v: vec![0.0; nb_cap * b * d],
            len: 0,
            r: Mat::zeros(nb_cap, nb_cap),
            balanced: 0,
            sk: vec![0.0; cache_blocks * b * d],
            sv: vec![0.0; cache_blocks * b * d],
            sorted_rows: 0,
            cut_rows: 0,
        }
    }

    /// Tokens decoded so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Token capacity (`nb_cap * b`).
    pub fn capacity(&self) -> usize {
        self.nb_cap * self.b
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn block_size(&self) -> usize {
        self.b
    }

    /// f32 elements this state allocates — the measured side of
    /// [`super::memory::decode_state_bytes`], asserted equal in
    /// `tests/decode_props.rs`.
    pub fn f32_elems(&self) -> usize {
        self.k.len() + self.v.len() + self.r.data.len() + self.sk.len() + self.sv.len()
    }

    /// Append one token and compute its attention output. This is the
    /// serving entry: `server::fallback::generate_batch` fans whole
    /// sequences over its pool and drives each one serially through here
    /// with a per-worker [`DecodeScratch`].
    /// [`super::engine::SinkhornEngine::decode_step_into`] is the
    /// alternative *lockstep* entry — one step across a batch of
    /// sequences at a time — and is bit-identical to this path
    /// (`tests/decode_props.rs`).
    pub fn step_into(
        &mut self,
        q_row: &[f32],
        k_row: &[f32],
        v_row: &[f32],
        sort_logits: &Mat,
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        self.step_with(q_row, k_row, v_row, sort_logits, &mut scratch.stream, out);
    }

    /// The decode step (DESIGN.md §Decode): append K/V, rebalance on a
    /// filled block boundary, stream `[sorted | local causal]`.
    ///
    /// `sort_logits` is the caller-maintained raw sort-logit matrix; only
    /// its top-left `(m, m)` corner is read, where `m` is the number of
    /// blocks started — rows for unstarted blocks may hold anything.
    pub(crate) fn step_with(
        &mut self,
        q_row: &[f32],
        k_row: &[f32],
        v_row: &[f32],
        sort_logits: &Mat,
        stream: &mut StreamState,
        out: &mut [f32],
    ) {
        let (b, d) = (self.b, self.d);
        assert!(self.len < self.capacity(), "decode capacity exhausted ({} tokens)", self.len);
        assert_eq!(q_row.len(), d, "q row must have d elements");
        assert_eq!(k_row.len(), d, "k row must have d elements");
        assert_eq!(v_row.len(), d, "v row must have d elements");
        assert_eq!(out.len(), d, "out row must have d elements");
        let t = self.len;
        let i = t / b; // the token's block
        self.k[t * d..(t + 1) * d].copy_from_slice(k_row);
        self.v[t * d..(t + 1) * d].copy_from_slice(v_row);
        self.len += 1;

        // Rebalance-on-boundary rule: the first token of block i makes m =
        // i + 1 blocks live; re-run Causal Sinkhorn Balancing over their
        // logits and refresh the gathered sorted cache. Every other step
        // reuses the caches untouched. Under SortCut, once the cut cache is
        // complete (cut_rows == c) no balanced row is ever read again —
        // prefix-stability froze them — so boundaries stop rebalancing
        // entirely and the per-step cost truly stops growing with the
        // prefix.
        let m = i + 1;
        let cache_live = match self.n_cut {
            None => true,
            Some(c) => self.cut_rows < c,
        };
        if self.balanced < m && !cache_live {
            self.balanced = m;
        }
        if self.balanced < m {
            assert!(
                sort_logits.rows >= m && sort_logits.cols >= m,
                "sort_logits must cover the {m} started blocks (got {}x{})",
                sort_logits.rows,
                sort_logits.cols
            );
            let sub = Mat::from_fn(m, m, |a, c| sort_logits[(a, c)]);
            let rm = causal_sinkhorn(&sub, self.n_iters, true);
            for row in 0..m {
                self.r.row_mut(row)[..m].copy_from_slice(rm.row(row));
            }
            self.balanced = m;
            // strict rows never weight the in-progress block, so gathering
            // over the first m blocks only ever reads complete ones (the
            // tail of block i is still zero-initialized and unused)
            let blocks = BlockedView::from_slice(&self.k[..m * b * d], m, b, d);
            let vblocks = BlockedView::from_slice(&self.v[..m * b * d], m, b, d);
            match self.n_cut {
                None => {
                    // full causal: cache block i's own sorted row
                    let w = &self.r.row(i)[..m];
                    if w.iter().sum::<f32>() > SUPPORT_EPS {
                        gather_block_into(w, &blocks, &mut self.sk[..b * d]);
                        gather_block_into(w, &vblocks, &mut self.sv[..b * d]);
                        self.sorted_rows = b;
                    } else {
                        self.sorted_rows = 0; // block 0: no sorted term
                    }
                }
                Some(c) => {
                    // SortCut: append the newly live cut rows (rows already
                    // cached are prefix-stable — module docs)
                    for j in self.cut_rows..c.min(m) {
                        let w = &self.r.row(j)[..m];
                        if w.iter().sum::<f32>() > SUPPORT_EPS {
                            let o = self.sorted_rows * d;
                            gather_block_into(w, &blocks, &mut self.sk[o..o + b * d]);
                            gather_block_into(w, &vblocks, &mut self.sv[o..o + b * d]);
                            self.sorted_rows += b;
                        }
                        self.cut_rows = j + 1;
                    }
                }
            }
        }

        // Streamed joint softmax for the single-row query: sorted segment
        // (if any), then the local causal window — rows i*b..=t of the K/V
        // cache. The causal bound is the segment length itself, so no mask
        // flag is needed.
        let scale = 1.0 / (d as f32).sqrt();
        out.fill(0.0);
        stream.reset(1);
        let qv = MatView::contiguous(q_row, 1, d);
        let mut y = MatViewMut::contiguous(out, 1, d);
        if self.sorted_rows > 0 {
            let ks = MatView::contiguous(&self.sk[..self.sorted_rows * d], self.sorted_rows, d);
            let vs = MatView::contiguous(&self.sv[..self.sorted_rows * d], self.sorted_rows, d);
            stream_segment_one(&qv, &ks, &vs, scale, stream, &mut y);
        }
        let lo = i * b;
        let nl = t - lo + 1;
        let lk = MatView::contiguous(&self.k[lo * d..(t + 1) * d], nl, d);
        let lv = MatView::contiguous(&self.v[lo * d..(t + 1) * d], nl, d);
        stream_segment_one(&qv, &lk, &lv, scale, stream, &mut y);
        normalize_rows(&mut y, &stream.l);
    }
}

/// Thin wrapper so the engine's `stream_segment` reads as a decode step:
/// single-row query, no in-segment causal mask (the local segment is
/// already bounded to the visible rows).
fn stream_segment_one(
    q: &MatView,
    kseg: &MatView,
    vseg: &MatView,
    scale: f32,
    st: &mut StreamState,
    y: &mut MatViewMut,
) {
    super::engine::stream_segment(q, kseg, vseg, scale, false, st, y);
}

/// One layer's incremental decode state inside a depth-L stack
/// (DESIGN.md §Model, §Decode): one [`DecodeState`] per attention head —
/// each head owns its K/V cache and cached balanced sort matrix in its
/// head dimension — plus the *caller-maintained* raw sort-logit matrix the
/// heads share (the layer has one SortNet; rows become live as blocks
/// complete, exactly like the single-layer decode rule). The
/// prefix-consistency argument is unchanged per head: every head balances
/// the same logits with the same strict-causal iteration, so each head's
/// caches stay sound independently, and the layer adds no new coupling.
pub struct LayerDecodeState {
    heads: Vec<DecodeState>,
    /// raw per-layer sort logits; the model writes row `i + 1` when block
    /// `i` completes (`sinkhorn::model::SinkhornStack::decode_step`)
    pub sort_logits: Mat,
}

impl LayerDecodeState {
    /// Fresh per-layer state: `n_heads` head caches of block shape
    /// `(b, d_head)` with `nb_cap` blocks of capacity each.
    pub fn new(
        n_heads: usize,
        b: usize,
        d_head: usize,
        nb_cap: usize,
        n_iters: usize,
        n_cut: Option<usize>,
    ) -> Self {
        assert!(n_heads > 0, "n_heads must be positive");
        LayerDecodeState {
            heads: (0..n_heads)
                .map(|_| DecodeState::new(b, d_head, nb_cap, n_iters, n_cut))
                .collect(),
            sort_logits: Mat::zeros(nb_cap, nb_cap),
        }
    }

    pub fn n_heads(&self) -> usize {
        self.heads.len()
    }

    /// Split the layer state into its per-head decode states and the
    /// shared sort-logit matrix — the borrow shape the batched stack step
    /// needs (DESIGN.md §Scheduler): each head state becomes one mutable
    /// engine decode task while every task reads the layer's logits.
    pub fn split_heads(&mut self) -> (&mut [DecodeState], &Mat) {
        let LayerDecodeState { heads, sort_logits } = self;
        (heads.as_mut_slice(), &*sort_logits)
    }

    /// Tokens decoded so far (all heads advance in lockstep).
    pub fn len(&self) -> usize {
        self.heads[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.heads[0].capacity()
    }

    /// f32 elements this layer state allocates — the measured side of
    /// [`super::memory::stack_decode_state_bytes`] (per layer), asserted
    /// in `tests/model_props.rs`.
    pub fn f32_elems(&self) -> usize {
        self.heads.iter().map(DecodeState::f32_elems).sum::<usize>() + self.sort_logits.data.len()
    }

    /// Step every head one token: `q`/`k`/`v`/`out` are flat
    /// `n_heads * d_head` rows (head-major), each head's slice fed through
    /// its own [`DecodeState::step_into`] against the shared sort logits.
    pub fn step_heads(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        scratch: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        let LayerDecodeState { heads, sort_logits } = self;
        let dh = heads[0].d();
        let flat = heads.len() * dh;
        assert_eq!(q.len(), flat, "q must hold n_heads * d_head elements");
        assert_eq!(k.len(), flat, "k must hold n_heads * d_head elements");
        assert_eq!(v.len(), flat, "v must hold n_heads * d_head elements");
        assert_eq!(out.len(), flat, "out must hold n_heads * d_head elements");
        for (h, head) in heads.iter_mut().enumerate() {
            let s = h * dh..(h + 1) * dh;
            let (qs, ks, vs) = (&q[s.clone()], &k[s.clone()], &v[s.clone()]);
            head.step_into(qs, ks, vs, sort_logits, scratch, &mut out[s]);
        }
    }
}

/// Per-step scratch for the serial decode entry ([`DecodeState::step_into`]):
/// the streaming-softmax carry for a single-row query. Reused across steps
/// and sequences; the engine's batched entry uses its per-worker
/// `Workspace` instead.
pub struct DecodeScratch {
    stream: StreamState,
}

impl DecodeScratch {
    pub fn new() -> Self {
        DecodeScratch { stream: StreamState::new(1) }
    }
}

impl Default for DecodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    // The heavy property suites (incremental == oracle across shapes,
    // boundaries and cuts; thread bit-invariance; memory accounting) live
    // in tests/decode_props.rs — only edge cases are covered here.
    use super::*;
    use crate::sinkhorn::attention::causal_decode_attention;
    use crate::util::rng::Rng;

    fn rand_rows(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
    }

    #[test]
    fn first_block_is_local_only_and_matches_oracle() {
        let (b, d, nb) = (3usize, 5usize, 2usize);
        let mut rng = Rng::new(0xDEC0);
        let q = rand_rows(&mut rng, b, d);
        let k = rand_rows(&mut rng, b, d);
        let v = rand_rows(&mut rng, b, d);
        let logits = rand_rows(&mut rng, nb, nb);
        let want = causal_decode_attention(&q, &k, &v, &logits, b, 4, None);
        let mut st = DecodeState::new(b, d, nb, 4, None);
        let mut scratch = DecodeScratch::new();
        let mut out = vec![0.0f32; d];
        for t in 0..b {
            st.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut out);
            assert_eq!(st.sorted_rows, 0, "block 0 has no sorted support");
            for (c, &got) in out.iter().enumerate() {
                assert!((got - want[(t, c)]).abs() <= 1e-5, "t={t} c={c}");
            }
        }
        assert_eq!(st.len(), b);
    }

    #[test]
    #[should_panic(expected = "decode capacity exhausted")]
    fn overflowing_capacity_panics() {
        let mut st = DecodeState::new(2, 3, 1, 2, None);
        let mut scratch = DecodeScratch::new();
        let (row, logits) = (vec![0.0f32; 3], Mat::zeros(1, 1));
        let mut out = vec![0.0f32; 3];
        for _ in 0..3 {
            st.step_into(&row, &row, &row, &logits, &mut scratch, &mut out);
        }
    }

    #[test]
    #[should_panic(expected = "n_cut must be in 1..=nb_cap")]
    fn rejects_oversized_cut() {
        DecodeState::new(2, 3, 2, 2, Some(3));
    }

    #[test]
    fn sortcut_cache_is_append_only() {
        let (b, d, nb) = (2usize, 4usize, 4usize);
        let mut rng = Rng::new(0xDEC1);
        let ell = nb * b;
        let q = rand_rows(&mut rng, ell, d);
        let k = rand_rows(&mut rng, ell, d);
        let v = rand_rows(&mut rng, ell, d);
        let logits = rand_rows(&mut rng, nb, nb);
        let mut st = DecodeState::new(b, d, nb, 4, Some(2));
        let mut scratch = DecodeScratch::new();
        let mut out = vec![0.0f32; d];
        let mut snapshot: Option<Vec<f32>> = None;
        for t in 0..ell {
            st.step_into(q.row(t), k.row(t), v.row(t), &logits, &mut scratch, &mut out);
            if st.sorted_rows == 2 * b {
                // the full cut is live: its contents must never change again
                match &snapshot {
                    None => snapshot = Some(st.sk[..2 * b * d].to_vec()),
                    Some(s) => assert_eq!(&st.sk[..2 * b * d], &s[..], "cut cache moved at t={t}"),
                }
            }
        }
        assert!(snapshot.is_some(), "cut never filled");
    }
}
