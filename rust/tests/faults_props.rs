//! Chaos battery for the fault-tolerant serving stack (DESIGN.md
//! §Faults) — runs with no artifacts and no XLA, in every build. The
//! contract under test:
//!
//! 1. under any seeded fault schedule (injected page-allocation
//!    failures, injected session-step panics), every request resolves —
//!    bitwise-correct output or one *stable* error — and once everything
//!    retires the page-pool ledger returns to zero, conserved;
//! 2. surviving sessions are **bitwise identical** to the fault-free run
//!    of the same cohort — fault isolation never perturbs neighbors —
//!    and replaying the same schedule reproduces the same outcomes;
//! 3. deadlines, cancellation, slow-client stalls and graceful drain
//!    each retire sessions with their documented stable error, release
//!    their admission slot (the wait queue drains), and free their
//!    pages;
//! 4. the TCP frontend survives mid-stream client disconnects — real
//!    ones and injected ones — without leaking the server-side session.
//!
//! Ledger assertions use `prefix_share: false` models: prefix caching
//! deliberately retains pages across retirements, which is exactly the
//! residue these tests must distinguish from a leak.

use std::time::{Duration, Instant};

use sinkhorn::server::faults::STEP_PANIC_MSG;
use sinkhorn::server::{
    BatchPolicy, FallbackConfig, FallbackModel, FaultPlan, FaultSpec, GenOptions, GenSession,
    Server, StepOutcome, TcpConfig, TcpFrontend, CANCELLED_MSG, DEADLINE_MSG, SHUTDOWN_MSG,
    STALL_MSG,
};
use sinkhorn::sinkhorn::pages::ALLOC_FAIL_MSG;
use sinkhorn::util::prop::{forall, Gen};
use sinkhorn::util::rng::Rng;

/// Tiny deterministic shapes: serial engine (auto cutoff), one block = 8
/// tokens, no prefix cache so a drained pool must read exactly zero.
fn tiny_cfg() -> FallbackConfig {
    FallbackConfig { seq_len: 32, d_model: 16, nb: 4, prefix_share: false, ..Default::default() }
}

/// A mixed cohort of (prompt, max_new) requests derived from `seed`.
fn cohort(seed: u64, n: usize) -> Vec<(Vec<i32>, usize)> {
    let mut rng = Rng::new(seed ^ 0xC0_807);
    (0..n)
        .map(|_| {
            let plen = rng.range_i64(1, 7) as usize; // < one block: no prefill
            let prompt: Vec<i32> = (0..plen).map(|_| rng.range_i64(0, 64) as i32).collect();
            let max_new = rng.range_i64(2, 9) as usize;
            (prompt, max_new)
        })
        .collect()
}

/// Drive a cohort through the isolated scheduler step path to
/// completion, exactly as `scheduler_loop` does: failed sessions retire
/// (dropped — pages return), survivors keep ticking. Returns per-request
/// `Ok(generated ids)` or `Err(stable message)`.
fn run_cohort(
    m: &FallbackModel,
    reqs: &[(Vec<i32>, usize)],
) -> Vec<Result<Vec<i32>, &'static str>> {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let mut sessions: Vec<Option<GenSession>> = Vec::new();
    let mut results: Vec<Option<Result<Vec<i32>, &'static str>>> = vec![None; reqs.len()];
    for (i, (p, n)) in reqs.iter().enumerate() {
        match catch_unwind(AssertUnwindSafe(|| m.open_session(p, *n))) {
            Ok(s) => sessions.push(Some(s)),
            Err(pay) => {
                sessions.push(None);
                results[i] = Some(Err(sinkhorn::server::faults::panic_msg(&*pay)));
            }
        }
    }
    let mut scratch = m.new_batch_scratch();
    loop {
        let mut idx = Vec::new();
        let mut live: Vec<&mut GenSession> = Vec::new();
        for (i, s) in sessions.iter_mut().enumerate() {
            if let Some(sess) = s {
                if !sess.done() {
                    idx.push(i);
                    live.push(sess);
                }
            }
        }
        if live.is_empty() {
            break;
        }
        let outs = m.step_sessions_isolated(&mut live, &mut scratch);
        for (i, o) in idx.into_iter().zip(outs) {
            if let StepOutcome::Failed(msg) = o {
                results[i] = Some(Err(msg));
                sessions[i] = None; // retire: the drop frees its pages
            }
        }
    }
    for (i, s) in sessions.into_iter().enumerate() {
        if let Some(sess) = s {
            results[i] = Some(Ok(sess.into_generated()));
        }
    }
    results.into_iter().map(|r| r.expect("every request resolves")).collect()
}

#[derive(Debug)]
struct ChaosCase {
    seed: u64,
    spec: FaultSpec,
    n_reqs: usize,
}

fn gen_chaos(g: &mut Gen) -> ChaosCase {
    let seed = g.rng.next_u64();
    let mut draw = |n: usize, horizon: usize| -> Vec<usize> {
        (0..n).map(|_| g.usize(0, horizon)).collect()
    };
    ChaosCase {
        seed,
        spec: FaultSpec {
            alloc_fail: draw(1 + g.size / 8, 64),
            step_panic: draw(1 + g.size / 8, 48),
            ..Default::default()
        },
        n_reqs: 3 + g.size % 3,
    }
}

/// Properties 1 + 2: randomized fault schedules over mixed cohorts —
/// survivors bitwise vs the fault-free twin, stable errors only,
/// replay-identical outcomes, ledger to zero.
#[test]
fn randomized_fault_schedules_leave_no_residue() {
    let oracle = FallbackModel::new(tiny_cfg()).unwrap();
    forall(10, 0xFA_017, gen_chaos, |c| {
        let reqs = cohort(c.seed, c.n_reqs);
        let run = |spec: &FaultSpec| -> (Vec<Result<Vec<i32>, &'static str>>, bool, usize) {
            let m = FallbackModel::with_faults(tiny_cfg(), FaultPlan::from_spec(spec)).unwrap();
            let res = run_cohort(&m, &reqs);
            let s = m.page_pool().stats();
            (res, s.conserved(), s.pages_in_use)
        };
        let (res, conserved, in_use) = run(&c.spec);
        if !conserved {
            return Err("pool ledger not conserved after faulted run".into());
        }
        if in_use != 0 {
            return Err(format!("{in_use} pages still in use after every retirement"));
        }
        for (r, (p, n)) in res.iter().zip(&reqs) {
            match r {
                Ok(ids) => {
                    let want = oracle.generate(p, *n);
                    if *ids != want {
                        return Err(format!(
                            "survivor diverged from fault-free twin: {ids:?} vs {want:?}"
                        ));
                    }
                }
                Err(msg) if *msg == ALLOC_FAIL_MSG || *msg == STEP_PANIC_MSG => {}
                Err(msg) => return Err(format!("unstable error surfaced: {msg:?}")),
            }
        }
        // replay: a fresh plan from the same spec reproduces everything
        let (res2, _, _) = run(&c.spec);
        if res != res2 {
            return Err("same schedule, different outcomes — injection is not replayable".into());
        }
        Ok(())
    });
}

/// Transient vs dense allocation faults: one scheduled ordinal is
/// recovered bitwise by committed-token replay; a dense run of ordinals
/// exhausts recovery and fails that session with the stable message —
/// either way later requests see a working pool.
#[test]
fn alloc_fault_density_decides_recovery_or_stable_failure() {
    let oracle = FallbackModel::new(tiny_cfg()).unwrap();
    let prompt = vec![3, 1, 4, 1, 5];
    // dense: the batch-step allocation fails (ordinal 0) AND the replay
    // recovery's re-allocation fails (ordinal 1) — recovery is defeated,
    // so the session must fail cleanly with the stable message
    let dense = FaultSpec { alloc_fail: vec![0, 1], ..Default::default() };
    let m = FallbackModel::with_faults(tiny_cfg(), FaultPlan::from_spec(&dense)).unwrap();
    let res = run_cohort(&m, &[(prompt.clone(), 6)]);
    assert_eq!(res, vec![Err(ALLOC_FAIL_MSG)]);
    // the pool itself is healthy: once the schedule runs past its
    // ordinals, the same model serves the same request bitwise
    let res = run_cohort(&m, &[(prompt.clone(), 6)]);
    assert_eq!(res, vec![Ok(oracle.generate(&prompt, 6))]);
    let s = m.page_pool().stats();
    assert!(s.conserved() && s.pages_in_use == 0, "residue: {s:?}");
}

/// Property 3, deadlines: a policy-default deadline of zero expires
/// queued work before admission; a per-request deadline expires an
/// admitted-but-paused session. Both surface the stable message, both
/// leave the server serving.
#[test]
fn deadlines_expire_queued_and_active_generations() {
    let policy = BatchPolicy {
        gen_deadline: Some(Duration::ZERO),
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start_fallback(tiny_cfg(), policy).unwrap();
    let e = server.handle.generate(vec![1, 2, 3], 5).unwrap_err();
    assert_eq!(e.to_string(), DEADLINE_MSG);
    // classify is deadline-free and keeps serving
    assert!(server.handle.classify((0..32).collect()).is_ok());
    server.shutdown().unwrap();

    // active expiry: outbox of 1 and an unread stream pause the session
    // mid-generation until its per-request deadline retires it
    let server = Server::start_fallback(tiny_cfg(), BatchPolicy::default()).unwrap();
    let opts = GenOptions { deadline: Some(Duration::from_millis(60)), outbox: 1 };
    let sg = server.handle.generate_streaming_with(vec![1, 2, 3], 20, opts).unwrap();
    let e = sg.reply.recv().unwrap().unwrap_err();
    assert_eq!(e.to_string(), DEADLINE_MSG);
    server.shutdown().unwrap();
}

/// Property 3, cancellation: cancelling a paused session frees its slot
/// (the queued neighbor admits and completes bitwise) and its admission
/// reservation; dropping the token receiver cancels implicitly.
#[test]
fn cancellation_releases_the_slot_and_the_queue_drains() {
    let oracle = FallbackModel::new(tiny_cfg()).unwrap();
    let policy = BatchPolicy {
        max_sessions: 1,
        max_wait: Duration::from_millis(1),
        mem_budget: 1 << 20,
        ..Default::default()
    };
    let server = Server::start_fallback(tiny_cfg(), policy).unwrap();
    // A: admitted, emits one token into its outbox of 1, pauses
    let sg = server
        .handle
        .generate_streaming_with(vec![9, 9], 20, GenOptions { deadline: None, outbox: 1 })
        .unwrap();
    // B: queued behind the only slot
    let h = server.handle.clone();
    let b = std::thread::spawn(move || h.generate(vec![5, 6, 7], 4));
    std::thread::sleep(Duration::from_millis(30));
    sg.cancel.cancel();
    let e = sg.reply.recv().unwrap().unwrap_err();
    assert_eq!(e.to_string(), CANCELLED_MSG);
    let resp = b.join().unwrap().expect("queued request must admit after the cancel");
    assert_eq!(resp.gen.unwrap(), oracle.generate(&[5, 6, 7], 4));
    server.shutdown().unwrap();

    // receiver drop = cancellation: the scheduler notices on its next
    // emission attempt and retires the session
    let server = Server::start_fallback(tiny_cfg(), BatchPolicy::default()).unwrap();
    let (toks, reply) = server.handle.generate_streaming(vec![1, 2, 3], 20).unwrap();
    drop(toks);
    let e = reply.recv().unwrap().unwrap_err();
    assert_eq!(e.to_string(), CANCELLED_MSG);
    server.shutdown().unwrap();
}

/// Property 3, slow clients: a full outbox past the stall timeout
/// retires the session with the stable error instead of blocking ticks.
#[test]
fn stalled_client_is_retired_with_the_stable_error() {
    let policy = BatchPolicy {
        stall_timeout: Duration::from_millis(50),
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start_fallback(tiny_cfg(), policy).unwrap();
    let sg = server
        .handle
        .generate_streaming_with(vec![2, 4, 6], 20, GenOptions { deadline: None, outbox: 1 })
        .unwrap();
    // never read sg.tokens: the outbox fills and the stall clock runs out
    let e = sg.reply.recv().unwrap().unwrap_err();
    assert_eq!(e.to_string(), STALL_MSG);
    // the scheduler survived its slow client
    assert!(server.handle.classify((0..32).collect()).is_ok());
    server.shutdown().unwrap();
}

/// Property 3, drain: with a zero drain window shutdown aborts in-flight
/// sessions with the stable message, refuses new work, exits, and the
/// pool reads zero. With a generous window a short generation finishes
/// bitwise first.
#[test]
fn drain_aborts_or_finishes_by_window() {
    let oracle = FallbackModel::new(tiny_cfg()).unwrap();
    // abrupt drain
    let model = FallbackModel::new(tiny_cfg()).unwrap();
    let pool = model.page_pool().clone();
    let policy = BatchPolicy {
        drain: Duration::ZERO,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start_fallback_model(model, policy).unwrap();
    let sg = server.handle.generate_streaming(vec![7, 7, 7], 25).unwrap();
    sg.0.recv().expect("session is live and streaming");
    server.handle.begin_shutdown().unwrap();
    let e = sg.1.recv().unwrap().unwrap_err();
    assert_eq!(e.to_string(), SHUTDOWN_MSG);
    let err = server.handle.classify((0..32).collect()).unwrap_err().to_string();
    assert!(
        err == SHUTDOWN_MSG || err.starts_with("server "),
        "post-drain work must refuse with a stable error, got {err:?}"
    );
    let t0 = Instant::now();
    while !server.is_finished() {
        assert!(t0.elapsed() < Duration::from_secs(10), "drain never finished");
        std::thread::sleep(Duration::from_millis(5));
    }
    let s = pool.stats();
    assert!(s.conserved() && s.pages_in_use == 0, "drain leaked pages: {s:?}");
    server.shutdown().unwrap();

    // graceful drain: the in-flight generation completes bitwise
    let policy = BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() };
    let server = Server::start_fallback(tiny_cfg(), policy).unwrap();
    let sg = server.handle.generate_streaming(vec![8, 1], 5).unwrap();
    server.handle.begin_shutdown().unwrap();
    let resp = sg.1.recv().unwrap().expect("short generation finishes inside the window");
    assert_eq!(resp.gen.unwrap(), oracle.generate(&[8, 1], 5));
    server.shutdown().unwrap();
}

/// Property 1 at the server level: a seeded schedule injected through
/// the whole stack under concurrent load — every request resolves with
/// bitwise output or a stable error, the executor survives, the ledger
/// returns to zero.
#[test]
fn server_survives_seeded_chaos_and_conserves_pages() {
    let oracle = FallbackModel::new(tiny_cfg()).unwrap();
    for seed in [11u64, 29] {
        let plan = FaultPlan::seeded(seed, 4, 60);
        let model = FallbackModel::with_faults(tiny_cfg(), plan.clone()).unwrap();
        let pool = model.page_pool().clone();
        let policy = BatchPolicy {
            max_sessions: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        };
        let server = Server::start_fallback_model(model, policy).unwrap();
        let reqs = cohort(seed, 6);
        let mut joins = Vec::new();
        for (p, n) in reqs {
            let h = server.handle.clone();
            joins.push(std::thread::spawn(move || (h.generate(p.clone(), n), p, n)));
        }
        for t in 0..4i32 {
            let toks: Vec<i32> = (0..32).map(|i| i * 3 + t).collect();
            server.handle.classify(toks).expect("classify rides through gen chaos");
        }
        for j in joins {
            let (r, p, n) = j.join().unwrap();
            match r {
                Ok(resp) => assert_eq!(
                    resp.gen.unwrap(),
                    oracle.generate(&p, n),
                    "seed {seed}: survivor diverged"
                ),
                Err(e) => {
                    // strictly the two injected messages: SESSION_PANIC_MSG
                    // here would mean a *genuine* panic leaked from a seam
                    let msg = e.to_string();
                    assert!(
                        [ALLOC_FAIL_MSG, STEP_PANIC_MSG].contains(&&msg[..]),
                        "seed {seed}: unstable error {msg:?}"
                    );
                }
            }
        }
        let (alloc_seen, step_seen, _, _) = plan.seen();
        assert!(alloc_seen > 0 && step_seen > 0, "schedule never reached its seams");
        server.shutdown().unwrap();
        let s = pool.stats();
        assert!(s.conserved() && s.pages_in_use == 0, "seed {seed} residue: {s:?}");
    }
}

/// Property 4, the real thing: a client that vanishes mid-stream. The
/// server-side write eventually fails, the generation is cancelled, its
/// pages return, and a concurrent connection is untouched.
#[test]
fn tcp_client_disconnect_mid_stream_frees_the_session() {
    use std::io::{BufRead, BufReader, Write};
    let model = FallbackModel::new(tiny_cfg()).unwrap();
    let pool = model.page_pool().clone();
    let policy = BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() };
    let server = Server::start_fallback_model(model, policy).unwrap();
    let fe = TcpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();

    let mut dead = std::net::TcpStream::connect(fe.addr).unwrap();
    dead.write_all(b"gen 25 1 2 3\n").unwrap();
    let mut reader = BufReader::new(dead.try_clone().unwrap());
    let mut l = String::new();
    reader.read_line(&mut l).unwrap();
    assert!(l.starts_with("tok "), "stream must have started: {l:?}");
    drop(reader);
    drop(dead); // hard-close mid-stream

    // the surviving connection serves a full request meanwhile
    let mut live = std::net::TcpStream::connect(fe.addr).unwrap();
    live.write_all(b"gen 3 5 5\n").unwrap();
    let mut reader = BufReader::new(live.try_clone().unwrap());
    let summary = loop {
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        if !l.starts_with("tok ") {
            break l;
        }
    };
    assert!(summary.starts_with("tokens="), "survivor got: {summary:?}");

    // the dead client's session retires once its write fails: poll the
    // ledger back to zero
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if s.pages_in_use == 0 && s.conserved() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "session leaked: {s:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(fe);
    server.shutdown().unwrap();
}

/// Property 4 over the HTTP gateway: an SSE subscriber that vanishes
/// mid-stream (hard close after the first `tok` event) fails the next
/// chunk write, which cancels the generation — the session retires, its
/// paged-cache reservation returns to the pool, its admission slot
/// frees, and a concurrent HTTP connection is untouched. The outbox
/// wrapper adds nothing the ledger can leak through.
#[test]
fn http_sse_client_disconnect_mid_stream_frees_the_session() {
    use std::io::{BufRead, BufReader, Read, Write};
    let model = FallbackModel::new(tiny_cfg()).unwrap();
    let pool = model.page_pool().clone();
    // one slot: the vanished client must *release* it or the follow-up
    // request can never admit — slot release is asserted, not assumed
    let policy = BatchPolicy {
        max_sessions: 1,
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start_fallback_model(model, policy).unwrap();
    let fe = sinkhorn::server::HttpFrontend::start("127.0.0.1:0", server.handle.clone()).unwrap();

    let body = r#"{"max_new":25,"tokens":[1,2,3]}"#;
    let mut dead = std::net::TcpStream::connect(fe.addr).unwrap();
    dead.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut reader = BufReader::new(dead.try_clone().unwrap());
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    assert!(status.starts_with("HTTP/1.1 200"), "stream must have started: {status:?}");
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
    }
    // one full chunk (= one SSE event) proves tokens are flowing
    let mut sz = String::new();
    reader.read_line(&mut sz).unwrap();
    let n = usize::from_str_radix(sz.trim(), 16).unwrap();
    assert!(n > 0, "first chunk is a tok event");
    let mut payload = vec![0u8; n];
    reader.read_exact(&mut payload).unwrap();
    drop(reader);
    drop(dead); // hard-close mid-SSE-flush

    // the admission slot frees: a fresh HTTP generate on the only slot
    // admits and streams to its done event
    let live_body = r#"{"max_new":3,"tokens":[5,5]}"#;
    let mut live = std::net::TcpStream::connect(fe.addr).unwrap();
    live.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{live_body}",
            live_body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut raw = Vec::new();
    BufReader::new(live).read_to_end(&mut raw).unwrap();
    let text = String::from_utf8_lossy(&raw);
    assert!(text.starts_with("HTTP/1.1 200"), "survivor got: {text:?}");
    assert!(text.contains("event: done"), "survivor never finished: {text:?}");

    // and the pages come home: poll the ledger back to zero
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if s.pages_in_use == 0 && s.conserved() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "session leaked: {s:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(fe);
    server.shutdown().unwrap();
}

/// Property 4, injected: a scheduled mid-stream disconnect closes the
/// connection deterministically at ordinal N; a scheduled stall only
/// delays. Replayable chaos without killing real sockets.
#[test]
fn tcp_injected_sock_faults_close_or_delay_deterministically() {
    use std::io::{BufRead, BufReader, Write};
    let server = Server::start_fallback(
        tiny_cfg(),
        BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
    )
    .unwrap();
    let spec = FaultSpec {
        sock_drop: vec![2],
        sock_stall: vec![0],
        stall_for: Duration::from_millis(30),
        ..Default::default()
    };
    let tcfg = TcpConfig { faults: FaultPlan::from_spec(&spec), ..Default::default() };
    let fe = TcpFrontend::start_with("127.0.0.1:0", server.handle.clone(), tcfg).unwrap();

    // first connection: stalled on write 0, dropped at write 2 — the
    // client sees exactly two tok lines, then EOF, never a summary
    let mut conn = std::net::TcpStream::connect(fe.addr).unwrap();
    conn.write_all(b"gen 10 1 2 3\n").unwrap();
    let mut reader = BufReader::new(conn);
    let mut lines = Vec::new();
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l).unwrap() == 0 {
            break;
        }
        lines.push(l);
    }
    assert_eq!(lines.len(), 2, "drop at ordinal 2 ends the stream: {lines:?}");
    assert!(lines.iter().all(|l| l.starts_with("tok ")), "no summary after a drop: {lines:?}");

    // the schedule is spent: the next connection streams to completion
    let mut conn = std::net::TcpStream::connect(fe.addr).unwrap();
    conn.write_all(b"gen 4 1 2 3\n").unwrap();
    let mut reader = BufReader::new(conn);
    let summary = loop {
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        if !l.starts_with("tok ") {
            break l;
        }
    };
    assert!(summary.starts_with("tokens="), "got: {summary:?}");
    drop(fe);
    server.shutdown().unwrap();
}
