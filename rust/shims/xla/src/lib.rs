//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The build container has no crates.io access (and no XLA shared
//! library), so `rust/Cargo.toml` resolves the `xla` dependency to this
//! path crate. It splits the API the coordinator uses into two tiers:
//!
//! * **Host-side literals — fully functional.** [`Literal`] construction,
//!   reshape, shape queries and `to_vec` roundtrips behave like the real
//!   crate, so `runtime::tensor::HostTensor` and the checkpoint store work
//!   (and stay unit-tested) in every build.
//! * **PJRT compile/execute — honest errors.** [`PjRtClient::cpu`] fails
//!   with a recognizable message. Callers that need a runtime degrade
//!   gracefully: the serving stack falls back to the pure-Rust blocked
//!   engine (`sinkhorn::server::fallback`), and `bench` keeps the targets
//!   that don't train (`engine`, `memory`). Link the real `xla` crate to
//!   execute AOT artifacts (DESIGN.md §2).

use std::fmt;

/// Error type for all fallible shim operations. Implements
/// `std::error::Error` so it converts into `anyhow::Error` via `?`.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// The message every PJRT entry point fails with — callers sniff for
/// "offline `xla` stub" when deciding to fall back.
const STUB_MSG: &str = "PJRT backend not available: this build links the offline `xla` stub \
     (rust/shims/xla); rebuild against the real `xla` crate to execute AOT artifacts";

fn stub_err<T>() -> Result<T> {
    Err(Error::msg(STUB_MSG))
}

/// XLA element types crossing the boundary (subset the coordinator uses,
/// plus `Pred`/`F64` so `match` arms keep their catch-all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    Pred,
}

/// Primitive type tags used when creating zeroed literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

impl PrimitiveType {
    fn element(self) -> ElementType {
        match self {
            PrimitiveType::F32 => ElementType::F32,
            PrimitiveType::S32 => ElementType::S32,
        }
    }
}

/// Shape of a (non-tuple) literal: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side tensor value. Fully functional in the shim.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Rust scalar types that map onto XLA element types.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<&[i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Zero-filled literal of the given shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        let data = match ty {
            PrimitiveType::F32 => Data::F32(vec![0.0; n]),
            PrimitiveType::S32 => Data::I32(vec![0; n]),
        };
        Literal { dims: dims.iter().map(|&d| d as i64).collect(), data }
    }

    fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::msg(format!(
                "reshape: {} elements into shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::Tuple(_) => return Err(Error::msg("array_shape on a tuple literal")),
        };
        Ok(ArrayShape { ty, dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error::msg(format!("literal is not {:?}", T::TY)))
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error::msg("to_tuple on a non-tuple literal")),
        }
    }

    /// Build a tuple literal (test helper; the real crate returns tuples
    /// from executions).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: Data::Tuple(elems) }
    }
}

/// Parsed HLO module. The stub cannot parse HLO text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable. Never constructed by the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// A device buffer holding one output. Never constructed by the stub.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// The PJRT client. [`PjRtClient::cpu`] always fails in the stub, which is
/// the signal the serving stack uses to select the pure-Rust fallback.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.5, -3.0]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.5, -3.0]);
        let s = lit.array_shape().unwrap();
        assert_eq!(s.dims(), &[3]);
        assert_eq!(s.ty(), ElementType::F32);
    }

    #[test]
    fn reshape_checks_count() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(lit.reshape(&[3]).is_err());
        // rank-0 from a single element
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);
    }

    #[test]
    fn zeros_have_right_type() {
        let z = Literal::create_from_shape(PrimitiveType::S32, &[2, 3]);
        assert_eq!(z.to_vec::<i32>().unwrap(), vec![0; 6]);
        assert!(z.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn pjrt_is_stubbed() {
        let e = PjRtClient::cpu().unwrap_err().to_string();
        assert!(e.contains("offline `xla` stub"), "{e}");
    }
}
