"""AOT exporter: lower every experiment's init/train/eval graphs to HLO text.

HLO *text* (never ``.serialize()``) is the interchange format — jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla_extension
0.5.1 backing the Rust ``xla`` crate rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Per experiment ``E`` this writes into ``artifacts/``:

  E.init.hlo.txt   (seed:i32) -> (params...)              reproducible init
  E.train.hlo.txt  (params..., m..., v..., step:f32, seed:i32, batch...)
                   -> (params'..., m'..., v'..., step', loss)
  E.eval.hlo.txt   (params..., batch...) -> family-specific outputs
  E.manifest.json  leaf names/shapes/dtypes + graph signatures

plus a global ``registry.json`` indexing all experiments for the Rust side.

Usage: ``python -m compile.aot --out-dir ../artifacts [--only lmw_tiny]``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}.get(str(dt), str(dt))


def _leaf_entries(tree):
    """Flatten a pytree into [(path-string, shape, dtype)] in tree order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append({"name": name, "shape": list(leaf.shape), "dtype": _dtype_str(leaf.dtype)})
    return out


def _init_fn(family, cfg):
    init = {"lm": model.lm_init, "cls": model.classifier_init, "seq2seq": model.seq2seq_init}[family]

    def fn(seed):
        return init(jax.random.PRNGKey(seed), cfg)

    return fn


def _batch_entries(shapes, family, is_eval):
    names = {
        "lm": ["tokens"],
        "cls": ["tokens", "labels"],
        "seq2seq": ["src", "tgt"],
    }[family]
    return [
        {"name": n, "shape": list(s.shape), "dtype": _dtype_str(s.dtype)}
        for n, s in zip(names, shapes)
    ]


EVAL_OUTPUTS = {
    "lm": [{"name": "loss"}],
    "cls": [{"name": "loss"}, {"name": "n_correct"}, {"name": "pred"}],
    "seq2seq": [{"name": "loss"}, {"name": "pred"}],
}


def export_experiment(exp: dict, out_dir: str, force: bool) -> dict:
    name, family, cfg, tcfg = exp["name"], exp["family"], exp["cfg"], exp["train"]
    paths = {
        "init": f"{name}.init.hlo.txt",
        "train": f"{name}.train.hlo.txt",
        "eval": f"{name}.eval.hlo.txt",
        "manifest": f"{name}.manifest.json",
    }
    done = all(os.path.exists(os.path.join(out_dir, p)) for p in paths.values())
    if done and not force:
        return paths

    t0 = time.time()
    init_fn = _init_fn(family, cfg)
    params_shape = jax.eval_shape(init_fn, jnp.int32(0))
    leaves = _leaf_entries(params_shape)

    # --- init graph ---
    lowered = jax.jit(init_fn).lower(jax.ShapeDtypeStruct((), jnp.int32))
    _write(out_dir, paths["init"], to_hlo_text(lowered))

    # --- train graph ---
    step_fn = train.make_train_step(family, cfg, tcfg)
    bshapes = train.batch_shapes(family, cfg, tcfg)
    f32s = jax.ShapeDtypeStruct((), jnp.float32)
    i32s = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(step_fn, keep_unused=True).lower(
        params_shape, params_shape, params_shape, f32s, i32s, *bshapes
    )
    _write(out_dir, paths["train"], to_hlo_text(lowered))

    # --- eval graph (seq2seq evals at doubled length) ---
    ecfg = configs.eval_cfg(exp)
    eval_fn = train.make_eval_step(family, ecfg)
    eshapes = train.eval_batch_shapes(family, ecfg, tcfg)
    lowered = jax.jit(eval_fn, keep_unused=True).lower(params_shape, *eshapes)
    _write(out_dir, paths["eval"], to_hlo_text(lowered))

    manifest = {
        "name": name,
        "family": family,
        "table": exp["table"],
        "cfg": cfg,
        "train_cfg": tcfg,
        "params": leaves,
        "n_leaves": len(leaves),
        "train_batch_inputs": _batch_entries(bshapes, family, False),
        "eval_batch_inputs": _batch_entries(eshapes, family, True),
        "eval_outputs": EVAL_OUTPUTS[family],
        "eval_cfg": ecfg,
        "artifacts": paths,
    }
    _write(out_dir, paths["manifest"], json.dumps(manifest, indent=1))
    print(f"  [{time.time() - t0:5.1f}s] {name}", flush=True)
    return paths


def _write(out_dir, rel, text):
    tmp = os.path.join(out_dir, rel + ".tmp")
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, os.path.join(out_dir, rel))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on experiment name or table")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    exps = configs.EXPERIMENTS
    if args.only:
        exps = [e for e in exps if args.only in e["name"] or args.only == e["table"]]
    print(f"exporting {len(exps)} experiments -> {out_dir}", flush=True)

    registry = {"experiments": []}
    for exp in exps:
        paths = export_experiment(exp, out_dir, args.force)
        registry["experiments"].append(
            {
                "name": exp["name"],
                "family": exp["family"],
                "table": exp["table"],
                "cfg": exp["cfg"],
                "train_cfg": exp["train"],
                "manifest": paths["manifest"],
            }
        )

    # merge with any previously exported experiments (partial --only runs)
    reg_path = os.path.join(out_dir, "registry.json")
    if os.path.exists(reg_path) and args.only:
        with open(reg_path) as f:
            old = json.load(f)
        have = {e["name"] for e in registry["experiments"]}
        for e in old.get("experiments", []):
            if e["name"] not in have:
                registry["experiments"].append(e)
    registry["experiments"].sort(key=lambda e: e["name"])
    with open(reg_path, "w") as f:
        json.dump(registry, f, indent=1)
    print(f"registry: {len(registry['experiments'])} experiments")


if __name__ == "__main__":
    main()
