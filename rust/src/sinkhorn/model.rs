//! Multi-layer, multi-head Sinkhorn Transformer forward stack on the
//! pure-Rust streaming engine (DESIGN.md §Model).
//!
//! The paper's results all come from a *stacked* Sinkhorn Transformer;
//! until this module the fallback model was a single attention step bolted
//! to a head. [`SinkhornStack`] is the real depth-L model:
//!
//! * **[`TransformerLayer`]** — pre-LayerNorm → per-layer SortNet →
//!   multi-head blocked sparse attention (every head streams through
//!   [`SinkhornEngine`]'s sorted+local path, sharing the layer's block
//!   mixing matrix) → per-head output projection summed into the residual →
//!   pre-LayerNorm GELU FFN. Layers can also be *bare* (no LayerNorm, no
//!   FFN, one head): a depth-1 bare stack reproduces the historical
//!   single-layer fallback **bitwise**, which `server::fallback` relies on.
//!   How SortNet logits become the mixing matrix is per-layer pluggable
//!   ([`SortStrategy`], DESIGN.md §Backends): [`SinkhornSort`] (the paper,
//!   the default, and the bitwise reference), `routing` (online k-means)
//!   or `local` (no sorted term) — see [`SinkhornStack::set_strategy`].
//! * **[`SinkhornStack`]** — owns the per-layer weights plus one pooled
//!   set of per-worker engine workspaces ([`EngineWorkspaces`]) and
//!   activation buffers ([`StackScratch`]) sized once for the deepest
//!   layer, so a forward pass allocates nothing per layer beyond the tiny
//!   `(nb, nb)` balanced sort matrix.
//! * **Incremental decode** — [`SinkhornStack::decode_step`] runs the full
//!   depth-L model one token at a time over a [`StackDecodeState`]
//!   (`Vec<`[`LayerDecodeState`]`>`): per layer, per head, the same cached
//!   causal Sinkhorn state as the single-layer path (DESIGN.md §Decode),
//!   with per-layer sort-logit rows produced by the decode-time SortNet
//!   rule (block `i`'s mean descriptor becomes row `i + 1` the moment
//!   block `i` fills). The prefix-consistency argument is per head and
//!   per layer, so stacking adds no new soundness obligations.
//!
//! **Numerics contract** (`tests/model_props.rs`): the stack matches the
//! naive per-layer oracle [`reference_stack_forward`] within
//! [`ENGINE_TOL`](super::engine::ENGINE_TOL) on the property-test shapes
//! (tile tails, multi-tile blocks, SortCut), stays bit-identical across
//! thread counts, and the incremental decode matches the full-prefix
//! per-layer oracle [`reference_stack_decode`] at every step. Projections
//! run in the naive oracle's accumulation order
//! ([`matmul_acc_ordered_into`]) to preserve the depth-1 bitwise
//! equivalence; the FFN, which has no bitwise heritage, uses the tiled
//! microkernels (fused bias + matmul, `LANES`-split LayerNorm — DESIGN.md
//! §Microkernels).
//!
//! [`reference_stack_forward`]: super::attention::reference_stack_forward
//! [`reference_stack_decode`]: super::attention::reference_stack_decode

use std::sync::Arc;

use anyhow::Result;

use super::decode::{DecodeScratch, LayerDecodeState};
use super::engine::{DecodeReq, EngineWorkspaces, PrefillReq, SinkhornEngine, SortLayout};
use super::matrix::{
    bias_rows_into, gelu, gelu_into, layernorm_into, layernorm_row_into, matmul_acc_into,
    matmul_acc_ordered_into, row_times, row_times_acc_into, row_times_into, Mat, MatView,
    MatViewMut,
};
use super::pages::PagePool;
use super::pool::WorkerPool;
use super::strategy::{Backend, SinkhornSort, SortStrategy};
use crate::util::rng::Rng;

/// Shape of a [`SinkhornStack`].
#[derive(Debug, Clone)]
pub struct StackConfig {
    /// fixed sequence length the stack's buffers are sized for
    pub seq_len: usize,
    pub d_model: usize,
    /// attention heads per layer; must divide `d_model`
    pub n_heads: usize,
    /// number of [`TransformerLayer`]s
    pub depth: usize,
    /// FFN hidden width; `0` disables LayerNorm + FFN entirely (*bare*
    /// layers — the historical single-layer fallback shape)
    pub d_ff: usize,
    /// sort blocks; must divide `seq_len`
    pub nb: usize,
    /// Sinkhorn balance iterations per sort matrix
    pub sinkhorn_iters: usize,
    /// strict-causal sort + within-block causal mask on the local term
    pub causal: bool,
    /// `Some(c)`: SortCut attention over the first `c` sorted blocks
    /// (paper §3.3; non-causal forward only — causal truncation is the
    /// decode path's job)
    pub n_cut: Option<usize>,
}

impl StackConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// rows per block
    pub fn block_rows(&self) -> usize {
        self.seq_len / self.nb
    }

    /// Bare layers (no LayerNorm, no FFN) — the legacy single-layer shape.
    pub fn bare_layers(&self) -> bool {
        self.d_ff == 0
    }

    pub fn validate(&self) -> Result<()> {
        if self.depth == 0 {
            anyhow::bail!("stack: depth must be positive");
        }
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            anyhow::bail!(
                "stack: n_heads {} must be positive and divide d_model {}",
                self.n_heads,
                self.d_model
            );
        }
        if self.nb == 0 || self.seq_len % self.nb != 0 {
            anyhow::bail!(
                "stack: nb {} must be positive and divide seq_len {}",
                self.nb,
                self.seq_len
            );
        }
        if let Some(c) = self.n_cut {
            if !(1..=self.nb).contains(&c) {
                anyhow::bail!("stack: n_cut {c} must be in 1..={}", self.nb);
            }
            if self.causal {
                anyhow::bail!(
                    "stack: causal + n_cut is not a batch-forward mode (SortCut decoding \
                     handles causal truncation — DESIGN.md §Decode)"
                );
            }
        }
        Ok(())
    }
}

/// LayerNorm affine parameters.
#[derive(Debug, Clone)]
pub struct LayerNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
}

impl LayerNorm {
    /// Identity-initialized (`gamma = 1`, `beta = 0`).
    pub fn identity(d: usize) -> Self {
        LayerNorm { gamma: vec![1.0; d], beta: vec![0.0; d] }
    }

    fn n_params(&self) -> usize {
        self.gamma.len() + self.beta.len()
    }
}

/// Pre-norm GELU feed-forward block: `x + W2 gelu(W1 ln(x) + b1) + b2`.
#[derive(Debug, Clone)]
pub struct Ffn {
    pub ln: LayerNorm,
    /// `(d_model, d_ff)`
    pub w1: Mat,
    pub b1: Vec<f32>,
    /// `(d_ff, d_model)`
    pub w2: Mat,
    pub b2: Vec<f32>,
}

impl Ffn {
    fn n_params(&self) -> usize {
        self.ln.n_params()
            + self.w1.data.len()
            + self.b1.len()
            + self.w2.data.len()
            + self.b2.len()
    }
}

/// One layer of the stack: optional pre-LayerNorm, per-head q/k/v/output
/// projections, the layer's SortNet head, and an optional FFN block.
/// `ln1`/`ffn` are `None` together in *bare* mode (`StackConfig::d_ff == 0`).
#[derive(Debug, Clone)]
pub struct TransformerLayer {
    /// pre-attention LayerNorm (`None` in bare mode)
    pub ln1: Option<LayerNorm>,
    /// per-head `(d_model, d_head)` query projections
    pub wq: Vec<Mat>,
    pub wk: Vec<Mat>,
    pub wv: Vec<Mat>,
    /// per-head `(d_head, d_model)` output projections, summed over heads
    pub wo: Vec<Mat>,
    /// `(d_model, nb)` SortNet head: block descriptor → destination logits
    pub sortnet: Mat,
    /// feed-forward block (`None` in bare mode)
    pub ffn: Option<Ffn>,
}

impl TransformerLayer {
    /// The historical single-layer fallback shape: one head, full-width
    /// projections, no LayerNorm, no FFN. A depth-1 stack of this layer is
    /// bit-identical to the pre-stack fallback forward.
    pub fn bare_single_head(wq: Mat, wk: Mat, wv: Mat, wo: Mat, sortnet: Mat) -> Self {
        TransformerLayer {
            ln1: None,
            wq: vec![wq],
            wk: vec![wk],
            wv: vec![wv],
            wo: vec![wo],
            sortnet,
            ffn: None,
        }
    }

    /// Deterministically seeded layer for `cfg` (identity LayerNorms, zero
    /// biases, `1/sqrt(fan_in)`-scaled weights).
    pub fn seeded(cfg: &StackConfig, rng: &mut Rng) -> Self {
        let (d, dh) = (cfg.d_model, cfg.d_head());
        let wscale = 1.0 / (d as f64).sqrt();
        let mut init = |rows: usize, cols: usize, scale: f64, tag: u64| {
            let mut r = rng.fork(tag.wrapping_mul(0x9E37).wrapping_add((rows * 31 + cols) as u64));
            Mat::from_fn(rows, cols, |_, _| (r.normal() * scale) as f32)
        };
        let mut head_mats = |rows: usize, cols: usize, tag0: u64| -> Vec<Mat> {
            (0..cfg.n_heads).map(|h| init(rows, cols, wscale, tag0 + h as u64)).collect()
        };
        let wq = head_mats(d, dh, 0x100);
        let wk = head_mats(d, dh, 0x200);
        let wv = head_mats(d, dh, 0x300);
        let wo = head_mats(dh, d, 0x400);
        let sortnet = init(d, cfg.nb, wscale, 0x500);
        let (ln1, ffn) = if cfg.bare_layers() {
            (None, None)
        } else {
            let ffn = Ffn {
                ln: LayerNorm::identity(d),
                w1: init(d, cfg.d_ff, wscale, 0x600),
                b1: vec![0.0; cfg.d_ff],
                w2: init(cfg.d_ff, d, 1.0 / (cfg.d_ff as f64).sqrt(), 0x700),
                b2: vec![0.0; d],
            };
            (Some(LayerNorm::identity(d)), Some(ffn))
        };
        TransformerLayer { ln1, wq, wk, wv, wo, sortnet, ffn }
    }

    /// Measured parameter count (asserted against the analytic
    /// `memory::stack_params` model in `tests/model_props.rs`).
    pub fn n_params(&self) -> usize {
        let proj: usize = self
            .wq
            .iter()
            .chain(&self.wk)
            .chain(&self.wv)
            .chain(&self.wo)
            .map(|m| m.data.len())
            .sum();
        proj
            + self.sortnet.data.len()
            + self.ln1.as_ref().map_or(0, LayerNorm::n_params)
            + self.ffn.as_ref().map_or(0, Ffn::n_params)
    }

    fn check_shapes(&self, cfg: &StackConfig) -> Result<()> {
        let (d, dh) = (cfg.d_model, cfg.d_head());
        for (name, ws, rows, cols) in [
            ("wq", &self.wq, d, dh),
            ("wk", &self.wk, d, dh),
            ("wv", &self.wv, d, dh),
            ("wo", &self.wo, dh, d),
        ] {
            if ws.len() != cfg.n_heads {
                anyhow::bail!("layer {name}: {} heads, config says {}", ws.len(), cfg.n_heads);
            }
            for m in ws.iter() {
                if (m.rows, m.cols) != (rows, cols) {
                    anyhow::bail!("layer {name}: ({}, {}) != ({rows}, {cols})", m.rows, m.cols);
                }
            }
        }
        if (self.sortnet.rows, self.sortnet.cols) != (d, cfg.nb) {
            anyhow::bail!("layer sortnet must be (d_model, nb)");
        }
        if cfg.bare_layers() != (self.ln1.is_none() && self.ffn.is_none()) {
            anyhow::bail!("layer LayerNorm/FFN presence must match StackConfig::d_ff");
        }
        if let Some(ffn) = &self.ffn {
            if (ffn.w1.rows, ffn.w1.cols) != (d, cfg.d_ff)
                || (ffn.w2.rows, ffn.w2.cols) != (cfg.d_ff, d)
                || ffn.b1.len() != cfg.d_ff
                || ffn.b2.len() != d
            {
                anyhow::bail!("layer FFN shapes must match (d_model, d_ff)");
            }
        }
        Ok(())
    }
}

/// Pooled activation + engine scratch for one forward pass, sized once for
/// the stack's (deepest) layer shape and reused across layers, calls and —
/// when the caller keeps it per worker — requests. The engine half is the
/// per-worker [`EngineWorkspaces`] the attention phase streams through.
pub struct StackScratch {
    /// LayerNorm output / projection source, `(ell, d)`
    h: Mat,
    /// per-head projected queries/keys/values/contexts, `(ell, d_head)` each
    qh: Vec<Mat>,
    kh: Vec<Mat>,
    vh: Vec<Mat>,
    ctx: Vec<Mat>,
    /// summed output projection, `(ell, d)`
    proj: Mat,
    /// FFN pre-activation and activation, `(ell, d_ff)`
    ff_pre: Mat,
    ff_act: Mat,
    /// FFN output, `(ell, d)` (empty in bare mode)
    ff_out: Mat,
    /// mean-pooled block descriptors, `(nb, d)`
    blk: Mat,
    /// per-worker engine workspaces, sized `(block_rows, d_head)`
    ws: EngineWorkspaces,
}

impl StackScratch {
    /// Scratch for `cfg` with one engine workspace per `threads` workers.
    pub fn new(cfg: &StackConfig, threads: usize) -> Self {
        let (ell, d, dh) = (cfg.seq_len, cfg.d_model, cfg.d_head());
        let head_bufs = || (0..cfg.n_heads).map(|_| Mat::zeros(ell, dh)).collect::<Vec<Mat>>();
        StackScratch {
            h: Mat::zeros(ell, d),
            qh: head_bufs(),
            kh: head_bufs(),
            vh: head_bufs(),
            ctx: head_bufs(),
            proj: Mat::zeros(ell, d),
            ff_pre: Mat::zeros(ell, cfg.d_ff),
            ff_act: Mat::zeros(ell, cfg.d_ff),
            ff_out: Mat::zeros(ell, if cfg.bare_layers() { 0 } else { d }),
            blk: Mat::zeros(cfg.nb, d),
            ws: EngineWorkspaces::new(threads, cfg.block_rows(), dh),
        }
    }

    /// f32 elements this scratch allocates — the measured side of
    /// `memory::stack_scratch_elems`, asserted in `tests/model_props.rs`.
    pub fn f32_elems(&self) -> usize {
        let heads: usize = self
            .qh
            .iter()
            .chain(&self.kh)
            .chain(&self.vh)
            .chain(&self.ctx)
            .map(|m| m.data.len())
            .sum();
        self.h.data.len()
            + heads
            + self.proj.data.len()
            + self.ff_pre.data.len()
            + self.ff_act.data.len()
            + self.ff_out.data.len()
            + self.blk.data.len()
            + self.ws.f32_elems()
    }
}

/// The depth-L Sinkhorn Transformer stack (DESIGN.md §Model): per-layer
/// weights, the engine that streams every head's attention, and one owned
/// [`StackScratch`] for the single-user [`Self::forward`] entry. Shared
/// (`&self`) entries take an explicit scratch so server workers can hold
/// one each.
pub struct SinkhornStack {
    pub cfg: StackConfig,
    pub layers: Vec<TransformerLayer>,
    /// per-layer sort backend (DESIGN.md §Backends); every constructor
    /// defaults to [`SinkhornSort`], which keeps the stack bitwise
    /// identical to the pre-trait code
    strategies: Vec<Arc<dyn SortStrategy>>,
    engine: SinkhornEngine,
    scratch: StackScratch,
}

impl SinkhornStack {
    /// Wrap explicit layers (shape-checked against `cfg`).
    pub fn new(
        cfg: StackConfig,
        layers: Vec<TransformerLayer>,
        engine: SinkhornEngine,
    ) -> Result<Self> {
        cfg.validate()?;
        if layers.len() != cfg.depth {
            anyhow::bail!("stack: {} layers, config says depth {}", layers.len(), cfg.depth);
        }
        for layer in &layers {
            layer.check_shapes(&cfg)?;
        }
        let scratch = StackScratch::new(&cfg, engine.threads());
        let reference: Arc<dyn SortStrategy> = Arc::new(SinkhornSort);
        let strategies = (0..cfg.depth).map(|_| reference.clone()).collect();
        Ok(SinkhornStack { cfg, layers, strategies, engine, scratch })
    }

    /// A deterministically seeded stack (the bench + test constructor).
    pub fn seeded(cfg: StackConfig, seed: u64, engine: SinkhornEngine) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Rng::new(seed);
        let layers = (0..cfg.depth)
            .map(|l| {
                let mut lr = rng.fork(0x57AC + l as u64);
                TransformerLayer::seeded(&cfg, &mut lr)
            })
            .collect();
        Self::new(cfg, layers, engine)
    }

    pub fn engine(&self) -> &SinkhornEngine {
        &self.engine
    }

    /// Install one sort backend on every layer (DESIGN.md §Backends).
    /// Existing decode states keep the strategy they were built with;
    /// states created by [`Self::decode_state`] afterwards pick up the
    /// new one — swap before opening sessions, not mid-sequence.
    pub fn set_strategy(&mut self, strategy: Arc<dyn SortStrategy>) {
        for s in self.strategies.iter_mut() {
            *s = strategy.clone();
        }
    }

    /// Install a sort backend on one layer (hybrid stacks — e.g. routing
    /// on the long-range middle layers, Sinkhorn elsewhere).
    pub fn set_layer_strategy(&mut self, layer: usize, strategy: Arc<dyn SortStrategy>) {
        self.strategies[layer] = strategy;
    }

    /// The per-layer sort strategies, in layer order.
    pub fn strategies(&self) -> &[Arc<dyn SortStrategy>] {
        &self.strategies
    }

    /// The backend of every layer when they agree, else `None` (mixed
    /// stacks have no single stable `sort_backend=` value to report).
    pub fn uniform_backend(&self) -> Option<Backend> {
        let first = self.strategies.first()?.backend();
        self.strategies.iter().all(|s| s.backend() == first).then_some(first)
    }

    /// Total stack parameters (layers only — embeddings and task heads
    /// belong to the caller).
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(TransformerLayer::n_params).sum()
    }

    /// A fresh scratch sized for this stack (per-worker callers hold one
    /// each; [`Self::forward`] uses the stack's own).
    pub fn new_scratch(&self) -> StackScratch {
        StackScratch::new(&self.cfg, self.engine.threads())
    }

    /// Forward pass in place over `x` (`(seq_len, d_model)` hidden states
    /// in, final hidden states out), using the stack's own scratch.
    pub fn forward(&mut self, x: &mut Mat) {
        let SinkhornStack { cfg, layers, strategies, engine, scratch } = self;
        check_input(cfg, x);
        for (layer, strat) in layers.iter().zip(strategies.iter()) {
            layer_forward(cfg, layer, strat.as_ref(), x, engine, scratch);
        }
    }

    /// [`Self::forward`] with a caller-owned scratch and engine — the
    /// shared-`&self` entry server workers use (one scratch per worker;
    /// per-request engines stay serial inside a request-parallel pool).
    /// Bit-identical to `forward` for any engine thread count.
    pub fn forward_with(&self, x: &mut Mat, engine: &SinkhornEngine, scratch: &mut StackScratch) {
        check_input(&self.cfg, x);
        for (layer, strat) in self.layers.iter().zip(self.strategies.iter()) {
            layer_forward(&self.cfg, layer, strat.as_ref(), x, engine, scratch);
        }
    }

    /// Forward a batch of sequences. Batches with at least one request
    /// per pool worker fan out request-parallel — one sequence per task,
    /// each worker reusing one private scratch and running the engine
    /// serially, so there are no nested thread pools and every worker
    /// carries whole L-layer requests. Smaller batches cannot fill the
    /// pool that way, so they run sequentially on the caller's thread
    /// through the stack's own engine — block-level parallelism per
    /// request, exactly the single-request scheduling, including its
    /// serial-below-the-spawn-payoff choice for tiny models. Either
    /// schedule is bit-identical to [`Self::forward_with`] per request
    /// (engine thread invariance), so batched and single forwards always
    /// agree bitwise.
    pub fn forward_batch(&self, xs: &mut [Mat], pool: &WorkerPool) {
        if xs.is_empty() {
            return;
        }
        if xs.len() < pool.threads() {
            let mut scratch = self.new_scratch();
            for x in xs.iter_mut() {
                self.forward_with(x, &self.engine, &mut scratch);
            }
            return;
        }
        let serial = SinkhornEngine::serial();
        let tasks: Vec<&mut Mat> = xs.iter_mut().collect();
        pool.run(
            tasks,
            || StackScratch::new(&self.cfg, 1),
            |scratch, x| self.forward_with(x, &serial, scratch),
        );
    }

    /// Fresh per-sequence incremental decode state: one
    /// [`LayerDecodeState`] per layer (per-head K/V caches + the layer's
    /// sort-logit matrix) plus per-layer descriptor accumulators.
    pub fn decode_state(&self) -> StackDecodeState {
        let cfg = &self.cfg;
        StackDecodeState {
            layers: (0..cfg.depth)
                .map(|l| {
                    LayerDecodeState::new(
                        cfg.n_heads,
                        cfg.block_rows(),
                        cfg.d_head(),
                        cfg.nb,
                        cfg.sinkhorn_iters,
                        cfg.n_cut,
                    )
                    .with_strategy(self.strategies[l].clone())
                })
                .collect(),
            desc: (0..cfg.depth).map(|_| vec![0.0; cfg.d_model]).collect(),
            len: 0,
        }
    }

    /// Fresh *paged* per-sequence decode state (DESIGN.md §Pages): same
    /// shape and step semantics as [`Self::decode_state`], but every
    /// head's caches are lazily allocated views over `pool`, and
    /// [`StackDecodeState::fork`] shares them by refcount — the substrate
    /// for prompt-prefix sharing in `server::fallback::open_session`.
    pub fn decode_state_paged(&self, pool: &PagePool, blocks_per_page: usize) -> StackDecodeState {
        let cfg = &self.cfg;
        StackDecodeState {
            layers: (0..cfg.depth)
                .map(|l| {
                    LayerDecodeState::new_paged(
                        cfg.n_heads,
                        cfg.block_rows(),
                        cfg.d_head(),
                        cfg.nb,
                        cfg.sinkhorn_iters,
                        cfg.n_cut,
                        pool,
                        blocks_per_page,
                    )
                    .with_strategy(self.strategies[l].clone())
                })
                .collect(),
            desc: (0..cfg.depth).map(|_| vec![0.0; cfg.d_model]).collect(),
            len: 0,
        }
    }

    /// Per-step decode scratch (hold one per worker / sequence driver).
    pub fn new_decode_scratch(&self) -> StackDecodeScratch {
        StackDecodeScratch::new(&self.cfg)
    }

    /// One incremental decode step of the full depth-L stack (DESIGN.md
    /// §Model, §Decode): `x_row` is the embedded token (`d_model`
    /// elements), `out` receives the final hidden row. Per layer:
    /// pre-norm, per-head q/k/v rows, every head's cached causal decode
    /// step against the layer's sort logits, output projection + residual,
    /// FFN — and at each block boundary the completed block's mean
    /// descriptor becomes the *next* block's sort-logit row (the
    /// decode-time SortNet rule, now per layer). O(depth · b · d) per
    /// step; matches [`reference_stack_decode`] within
    /// [`ENGINE_TOL`](super::engine::ENGINE_TOL) at every step
    /// (`tests/model_props.rs`).
    ///
    /// [`reference_stack_decode`]: super::attention::reference_stack_decode
    pub fn decode_step(
        &self,
        st: &mut StackDecodeState,
        x_row: &[f32],
        scratch: &mut StackDecodeScratch,
        out: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let (d, dh, heads, nb) = (cfg.d_model, cfg.d_head(), cfg.n_heads, cfg.nb);
        let b = cfg.block_rows();
        assert_eq!(st.layers.len(), cfg.depth, "decode state depth mismatch");
        assert_eq!(x_row.len(), d, "x row must have d_model elements");
        assert_eq!(out.len(), d, "out row must have d_model elements");
        assert!(st.len < cfg.seq_len, "decode capacity exhausted ({} tokens)", st.len);
        let t = st.len;
        scratch.x.copy_from_slice(x_row);
        for (l, layer) in self.layers.iter().enumerate() {
            // pre-norm (bare layers read the residual stream directly)
            let h: &[f32] = match &layer.ln1 {
                Some(ln) => {
                    layernorm_row_into(&scratch.x, &ln.gamma, &ln.beta, &mut scratch.h);
                    &scratch.h
                }
                None => &scratch.x,
            };
            for hd in 0..heads {
                let s = hd * dh..(hd + 1) * dh;
                row_times_into(h, &layer.wq[hd], &mut scratch.q[s.clone()]);
                row_times_into(h, &layer.wk[hd], &mut scratch.k[s.clone()]);
                row_times_into(h, &layer.wv[hd], &mut scratch.v[s]);
            }
            st.layers[l].step_heads(
                &scratch.q,
                &scratch.k,
                &scratch.v,
                &mut scratch.stream,
                &mut scratch.ctx,
            );
            // descriptor accumulation + decode-time SortNet rule: block
            // i's mean descriptor becomes sort-logit row i + 1 the moment
            // block i fills (rows are written before the causal balance
            // first reads them, and never rewritten)
            for (c, a) in st.desc[l].iter_mut().enumerate() {
                *a += h[c];
            }
            if (t + 1) % b == 0 {
                let i = t / b;
                if i + 1 < nb {
                    let dacc = &mut st.desc[l];
                    for a in dacc.iter_mut() {
                        *a /= b as f32;
                    }
                    let row = row_times(dacc, &layer.sortnet);
                    st.layers[l].sort_logits.row_mut(i + 1).copy_from_slice(&row);
                }
                st.desc[l].fill(0.0);
            }
            // per-head output projection summed into the residual stream
            scratch.proj.fill(0.0);
            for hd in 0..heads {
                row_times_acc_into(
                    &scratch.ctx[hd * dh..(hd + 1) * dh],
                    &layer.wo[hd],
                    &mut scratch.proj,
                );
            }
            for (c, xo) in scratch.x.iter_mut().enumerate() {
                *xo += scratch.proj[c];
            }
            if let Some(ffn) = &layer.ffn {
                layernorm_row_into(&scratch.x, &ffn.ln.gamma, &ffn.ln.beta, &mut scratch.h);
                scratch.ff_pre.copy_from_slice(&ffn.b1);
                {
                    let hv = MatView::contiguous(&scratch.h, 1, d);
                    let mut pre = MatViewMut::contiguous(&mut scratch.ff_pre, 1, cfg.d_ff);
                    matmul_acc_into(&hv, &ffn.w1.view(), &mut pre);
                }
                for (o, &p) in scratch.ff_act.iter_mut().zip(scratch.ff_pre.iter()) {
                    *o = gelu(p);
                }
                scratch.ff_out.copy_from_slice(&ffn.b2);
                {
                    let av = MatView::contiguous(&scratch.ff_act, 1, cfg.d_ff);
                    let mut ov = MatViewMut::contiguous(&mut scratch.ff_out, 1, d);
                    matmul_acc_into(&av, &ffn.w2.view(), &mut ov);
                }
                for (xo, &f) in scratch.x.iter_mut().zip(scratch.ff_out.iter()) {
                    *xo += f;
                }
            }
        }
        st.len += 1;
        out.copy_from_slice(&scratch.x);
    }

    /// Scratch for [`Self::decode_step_batch`]: per-session row buffers
    /// (grown on demand as the session count rises) plus one pooled
    /// [`EngineWorkspaces`] the engine's decode tasks stream through. One
    /// per scheduler, reused across every tick.
    pub fn new_batch_scratch(&self) -> StackBatchScratch {
        StackBatchScratch {
            per: Vec::new(),
            ws: EngineWorkspaces::new(self.engine.threads(), 1, self.cfg.d_head()),
        }
    }

    /// One incremental decode step for a *batch of sessions* (DESIGN.md
    /// §Scheduler): every [`StackStepReq`] advances its own
    /// [`StackDecodeState`] by one token, exactly like
    /// [`Self::decode_step`], but the per-head attention steps of **all**
    /// sessions are flattened into one fused `(session, head)` task list
    /// per layer and driven through the engine's pooled decode entry
    /// ([`SinkhornEngine::decode_steps_with`]) — not a loop over
    /// `decode_step`. The serving scheduler's tick loop is the consumer:
    /// one call here advances every active session by one token.
    ///
    /// Per layer: phase A runs each session's pre-norm + per-head q/k/v
    /// row projections (cheap row kernels, caller thread); phase B is the
    /// fused engine pass over `sessions × heads` cached-causal decode
    /// tasks; phase C applies each session's descriptor accumulation,
    /// decode-time SortNet rule, output projection + residual, and FFN.
    /// Every per-session operation is the same kernel in the same order as
    /// `decode_step`, and the engine's decode tasks are placement-
    /// independent, so the batched step is **bit-identical** to stepping
    /// each session alone, for any cohort composition and any thread count
    /// (`tests/decode_props.rs`).
    pub fn decode_step_batch(&self, mut reqs: Vec<StackStepReq>, scratch: &mut StackBatchScratch) {
        let cfg = &self.cfg;
        if reqs.is_empty() {
            return;
        }
        let (d, dh, heads, nb) = (cfg.d_model, cfg.d_head(), cfg.n_heads, cfg.nb);
        let b = cfg.block_rows();
        while scratch.per.len() < reqs.len() {
            scratch.per.push(StackDecodeScratch::new(cfg));
        }
        for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
            assert_eq!(r.st.layers.len(), cfg.depth, "decode state depth mismatch");
            assert_eq!(r.x.len(), d, "x row must have d_model elements");
            assert_eq!(r.out.len(), d, "out row must have d_model elements");
            assert!(r.st.len < cfg.seq_len, "decode capacity exhausted ({} tokens)", r.st.len);
            sc.x.copy_from_slice(r.x);
        }
        for (l, layer) in self.layers.iter().enumerate() {
            // phase A: pre-norm + per-head q/k/v projection rows, per session
            for sc in scratch.per[..reqs.len()].iter_mut() {
                let h: &[f32] = match &layer.ln1 {
                    Some(ln) => {
                        layernorm_row_into(&sc.x, &ln.gamma, &ln.beta, &mut sc.h);
                        &sc.h
                    }
                    None => &sc.x,
                };
                for hd in 0..heads {
                    let s = hd * dh..(hd + 1) * dh;
                    row_times_into(h, &layer.wq[hd], &mut sc.q[s.clone()]);
                    row_times_into(h, &layer.wk[hd], &mut sc.k[s.clone()]);
                    row_times_into(h, &layer.wv[hd], &mut sc.v[s]);
                }
            }
            // phase B: the fused (session, head) decode task list, one
            // engine pass over the pooled workspaces
            let mut dreqs: Vec<DecodeReq> = Vec::with_capacity(reqs.len() * heads);
            for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
                let (hstates, sort_logits) = r.st.layers[l].split_heads();
                for (hd, (hstate, ctx)) in
                    hstates.iter_mut().zip(sc.ctx.chunks_mut(dh)).enumerate()
                {
                    let s = hd * dh..(hd + 1) * dh;
                    dreqs.push(DecodeReq {
                        state: hstate,
                        q: &sc.q[s.clone()],
                        k: &sc.k[s.clone()],
                        v: &sc.v[s],
                        sort_logits,
                        out: ctx,
                    });
                }
            }
            self.engine.decode_steps_with(dreqs, &mut scratch.ws);
            // phase C: descriptor + SortNet rule, output projection, FFN
            for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
                let t = r.st.len;
                let h: &[f32] = if layer.ln1.is_some() { &sc.h } else { &sc.x };
                for (c, a) in r.st.desc[l].iter_mut().enumerate() {
                    *a += h[c];
                }
                if (t + 1) % b == 0 {
                    let i = t / b;
                    if i + 1 < nb {
                        let dacc = &mut r.st.desc[l];
                        for a in dacc.iter_mut() {
                            *a /= b as f32;
                        }
                        let row = row_times(dacc, &layer.sortnet);
                        r.st.layers[l].sort_logits.row_mut(i + 1).copy_from_slice(&row);
                    }
                    r.st.desc[l].fill(0.0);
                }
                sc.proj.fill(0.0);
                for hd in 0..heads {
                    let ctx = &sc.ctx[hd * dh..(hd + 1) * dh];
                    row_times_acc_into(ctx, &layer.wo[hd], &mut sc.proj);
                }
                for (c, xo) in sc.x.iter_mut().enumerate() {
                    *xo += sc.proj[c];
                }
                if let Some(ffn) = &layer.ffn {
                    layernorm_row_into(&sc.x, &ffn.ln.gamma, &ffn.ln.beta, &mut sc.h);
                    sc.ff_pre.copy_from_slice(&ffn.b1);
                    {
                        let hv = MatView::contiguous(&sc.h, 1, d);
                        let mut pre = MatViewMut::contiguous(&mut sc.ff_pre, 1, cfg.d_ff);
                        matmul_acc_into(&hv, &ffn.w1.view(), &mut pre);
                    }
                    for (o, &p) in sc.ff_act.iter_mut().zip(sc.ff_pre.iter()) {
                        *o = gelu(p);
                    }
                    sc.ff_out.copy_from_slice(&ffn.b2);
                    {
                        let av = MatView::contiguous(&sc.ff_act, 1, cfg.d_ff);
                        let mut ov = MatViewMut::contiguous(&mut sc.ff_out, 1, d);
                        matmul_acc_into(&av, &ffn.w2.view(), &mut ov);
                    }
                    for (xo, &f) in sc.x.iter_mut().zip(sc.ff_out.iter()) {
                        *xo += f;
                    }
                }
            }
        }
        for (r, sc) in reqs.iter_mut().zip(scratch.per.iter()) {
            r.st.len += 1;
            r.out.copy_from_slice(&sc.x);
        }
    }

    /// Pooled scratch for [`Self::prefill_batch`]: per-session chunk
    /// buffers sized for a full `seq_len` of rows (grown on demand as the
    /// session count rises) plus the engine workspaces the fused
    /// `(session, head)` chunk tasks stream through. The serving layer
    /// holds one per scheduler / opener, reused across every chunk.
    pub fn new_prefill_scratch(&self) -> StackPrefillScratch {
        StackPrefillScratch {
            per: Vec::new(),
            ws: EngineWorkspaces::new(self.engine.threads(), 1, self.cfg.d_head()),
        }
    }

    /// Chunked prompt ingestion for one sequence (DESIGN.md §Prefill):
    /// append `n` embedded prompt rows (`(n, d_model)` row-major `xs`) to
    /// `st` in one pass instead of `n` [`Self::decode_step`] calls.
    /// `out`, when given, receives the final hidden rows. Sugar over
    /// [`Self::prefill_batch`] with a single request.
    pub fn prefill(
        &self,
        st: &mut StackDecodeState,
        xs: &[f32],
        scratch: &mut StackPrefillScratch,
        out: Option<&mut [f32]>,
    ) {
        self.prefill_batch(vec![StackPrefillReq { st, xs, out }], scratch);
    }

    /// Chunked prefill for a *batch of sessions* (DESIGN.md §Prefill):
    /// every [`StackPrefillReq`] advances its own [`StackDecodeState`] by
    /// a whole chunk of embedded prompt rows, through the same three
    /// phases as [`Self::decode_step_batch`] — but phases A and C loop
    /// over the chunk's tokens on the caller thread, and phase B hands
    /// each `(session, head)` pair its *entire* chunk as one engine task
    /// ([`SinkhornEngine::prefill_chunks_with`]), so a prompt costs
    /// `depth` engine passes of `sessions × heads` chunk tasks instead of
    /// `ℓ` lockstep ticks.
    ///
    /// Bitwise contract (`tests/prefill_props.rs`): every per-token
    /// operation is the same kernel in the same order as `decode_step`.
    /// The one reordering is that the decode-time SortNet rule runs in
    /// phase A, *before* the chunk's attention, instead of after each
    /// token's — sound because row `i + 1` is written from block `i`'s
    /// mean pre-norm descriptor (a pure function of the layer's inputs,
    /// untouched by this layer's attention) and is first *read* by tokens
    /// of block `i + 1`, which phase A visits strictly later. Rows stay
    /// write-once, values and read order are identical, so chunked
    /// prefill is bit-identical to token-by-token decoding — across
    /// block boundaries, partial tails, SortCut cuts, paged/mono stores,
    /// and thread counts.
    pub fn prefill_batch(
        &self,
        mut reqs: Vec<StackPrefillReq>,
        scratch: &mut StackPrefillScratch,
    ) {
        let cfg = &self.cfg;
        if reqs.is_empty() {
            return;
        }
        let (d, dh, heads, nb) = (cfg.d_model, cfg.d_head(), cfg.n_heads, cfg.nb);
        let b = cfg.block_rows();
        let n_cap = cfg.seq_len;
        while scratch.per.len() < reqs.len() {
            scratch.per.push(PrefillBuf::new(cfg));
        }
        for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
            assert_eq!(r.st.layers.len(), cfg.depth, "decode state depth mismatch");
            assert!(r.xs.len() % d == 0, "prefill xs must be (n, d_model) row-major");
            let n = r.xs.len() / d;
            assert!(n > 0, "prefill chunk must carry at least one token");
            assert!(
                r.st.len + n <= cfg.seq_len,
                "prefill chunk of {n} tokens overflows decode capacity ({} + {n} > {})",
                r.st.len,
                cfg.seq_len
            );
            if let Some(out) = &r.out {
                assert_eq!(out.len(), n * d, "prefill out must match xs's (n, d_model) shape");
            }
            sc.xs[..n * d].copy_from_slice(r.xs);
        }
        for (l, layer) in self.layers.iter().enumerate() {
            // phase A: per token — pre-norm, per-head q/k/v rows,
            // descriptor accumulation + the SortNet boundary rule, so
            // every sort-logit row a chunk task will read is live before
            // phase B starts (write-once, same values as the step path)
            for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
                let n = r.xs.len() / d;
                for j in 0..n {
                    let t = r.st.len + j;
                    let x_row = &sc.xs[j * d..(j + 1) * d];
                    let h: &[f32] = match &layer.ln1 {
                        Some(ln) => {
                            let h_row = &mut sc.hs[j * d..(j + 1) * d];
                            layernorm_row_into(x_row, &ln.gamma, &ln.beta, h_row);
                            &sc.hs[j * d..(j + 1) * d]
                        }
                        None => x_row,
                    };
                    for hd in 0..heads {
                        let o = (hd * n_cap + j) * dh;
                        row_times_into(h, &layer.wq[hd], &mut sc.qs[o..o + dh]);
                        row_times_into(h, &layer.wk[hd], &mut sc.ks[o..o + dh]);
                        row_times_into(h, &layer.wv[hd], &mut sc.vs[o..o + dh]);
                    }
                    for (c, a) in r.st.desc[l].iter_mut().enumerate() {
                        *a += h[c];
                    }
                    if (t + 1) % b == 0 {
                        let i = t / b;
                        if i + 1 < nb {
                            let dacc = &mut r.st.desc[l];
                            for a in dacc.iter_mut() {
                                *a /= b as f32;
                            }
                            let row = row_times(dacc, &layer.sortnet);
                            r.st.layers[l].sort_logits.row_mut(i + 1).copy_from_slice(&row);
                        }
                        r.st.desc[l].fill(0.0);
                    }
                }
            }
            // phase B: one fused engine pass — each (session, head) task
            // ingests its whole chunk through the step-path op order
            let mut preqs: Vec<PrefillReq> = Vec::with_capacity(reqs.len() * heads);
            for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
                let n = r.xs.len() / d;
                let (hstates, sort_logits) = r.st.layers[l].split_heads();
                for (hd, (hstate, ctx)) in
                    hstates.iter_mut().zip(sc.ctx.chunks_mut(n_cap * dh)).enumerate()
                {
                    let o = hd * n_cap * dh;
                    preqs.push(PrefillReq {
                        state: hstate,
                        q: &sc.qs[o..o + n * dh],
                        k: &sc.ks[o..o + n * dh],
                        v: &sc.vs[o..o + n * dh],
                        sort_logits,
                        out: &mut ctx[..n * dh],
                    });
                }
            }
            self.engine.prefill_chunks_with(preqs, &mut scratch.ws);
            // phase C: per token — output projection + residual, FFN
            for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
                let n = r.xs.len() / d;
                for j in 0..n {
                    sc.proj.fill(0.0);
                    for hd in 0..heads {
                        let o = (hd * n_cap + j) * dh;
                        row_times_acc_into(&sc.ctx[o..o + dh], &layer.wo[hd], &mut sc.proj);
                    }
                    let x_row = &mut sc.xs[j * d..(j + 1) * d];
                    for (c, xo) in x_row.iter_mut().enumerate() {
                        *xo += sc.proj[c];
                    }
                    if let Some(ffn) = &layer.ffn {
                        let h_row = &mut sc.hs[j * d..(j + 1) * d];
                        layernorm_row_into(x_row, &ffn.ln.gamma, &ffn.ln.beta, h_row);
                        sc.ff_pre.copy_from_slice(&ffn.b1);
                        {
                            let hv = MatView::contiguous(h_row, 1, d);
                            let mut pre = MatViewMut::contiguous(&mut sc.ff_pre, 1, cfg.d_ff);
                            matmul_acc_into(&hv, &ffn.w1.view(), &mut pre);
                        }
                        for (o, &p) in sc.ff_act.iter_mut().zip(sc.ff_pre.iter()) {
                            *o = gelu(p);
                        }
                        sc.ff_out.copy_from_slice(&ffn.b2);
                        {
                            let av = MatView::contiguous(&sc.ff_act, 1, cfg.d_ff);
                            let mut ov = MatViewMut::contiguous(&mut sc.ff_out, 1, d);
                            matmul_acc_into(&av, &ffn.w2.view(), &mut ov);
                        }
                        for (xo, &f) in x_row.iter_mut().zip(sc.ff_out.iter()) {
                            *xo += f;
                        }
                    }
                }
            }
        }
        for (r, sc) in reqs.iter_mut().zip(scratch.per.iter_mut()) {
            let n = r.xs.len() / d;
            r.st.len += n;
            if let Some(out) = r.out.as_deref_mut() {
                out.copy_from_slice(&sc.xs[..n * d]);
            }
        }
    }
}

/// One session's slice of a batched stack decode step
/// ([`SinkhornStack::decode_step_batch`], DESIGN.md §Scheduler): its
/// per-sequence depth-L state, the embedded input row (`d_model`
/// elements), and the output row the final hidden state lands in.
pub struct StackStepReq<'a> {
    pub st: &'a mut StackDecodeState,
    pub x: &'a [f32],
    pub out: &'a mut [f32],
}

/// Pooled scratch for [`SinkhornStack::decode_step_batch`]: one
/// [`StackDecodeScratch`]-worth of row buffers per session (grown on
/// demand, never shrunk) plus the per-worker engine workspaces the fused
/// `(session, head)` decode tasks stream through. The serving scheduler
/// holds exactly one, for its whole lifetime.
pub struct StackBatchScratch {
    per: Vec<StackDecodeScratch>,
    ws: EngineWorkspaces,
}

/// One session's slice of a batched chunked prefill
/// ([`SinkhornStack::prefill_batch`], DESIGN.md §Prefill): its
/// per-sequence depth-L state, the embedded prompt rows (`(n, d_model)`
/// row-major), and optionally a same-shape buffer for the final hidden
/// rows (prompt ingestion usually discards them — only the *next* token's
/// step needs a logit — so `None` skips the copy).
pub struct StackPrefillReq<'a> {
    pub st: &'a mut StackDecodeState,
    pub xs: &'a [f32],
    pub out: Option<&'a mut [f32]>,
}

/// Pooled scratch for [`SinkhornStack::prefill_batch`]: one
/// `PrefillBuf`-worth of chunk buffers per session (grown on demand,
/// never shrunk) plus the per-worker engine workspaces the fused
/// `(session, head)` chunk tasks stream through.
pub struct StackPrefillScratch {
    per: Vec<PrefillBuf>,
    ws: EngineWorkspaces,
}

/// Per-session chunk buffers for prefill: residual-stream and pre-norm
/// rows for up to `seq_len` tokens, head-major projected Q/K/V and
/// context (`(heads, seq_len, d_head)` — each head's chunk rows are
/// contiguous, so phase B hands the engine plain slices), and the row
/// scratch the per-token phase-C kernels reuse.
struct PrefillBuf {
    xs: Vec<f32>,
    hs: Vec<f32>,
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    ctx: Vec<f32>,
    proj: Vec<f32>,
    ff_pre: Vec<f32>,
    ff_act: Vec<f32>,
    ff_out: Vec<f32>,
}

impl PrefillBuf {
    fn new(cfg: &StackConfig) -> Self {
        let (n_cap, d) = (cfg.seq_len, cfg.d_model);
        PrefillBuf {
            xs: vec![0.0; n_cap * d],
            hs: vec![0.0; n_cap * d],
            qs: vec![0.0; n_cap * d],
            ks: vec![0.0; n_cap * d],
            vs: vec![0.0; n_cap * d],
            ctx: vec![0.0; n_cap * d],
            proj: vec![0.0; d],
            ff_pre: vec![0.0; cfg.d_ff],
            ff_act: vec![0.0; cfg.d_ff],
            ff_out: vec![0.0; if cfg.bare_layers() { 0 } else { d }],
        }
    }
}

/// Per-sequence incremental decode state for the whole stack: one
/// [`LayerDecodeState`] per layer plus the per-layer running
/// block-descriptor accumulators (mean of the layer's pre-norm inputs over
/// the in-progress block).
pub struct StackDecodeState {
    layers: Vec<LayerDecodeState>,
    desc: Vec<Vec<f32>>,
    len: usize,
}

impl StackDecodeState {
    /// Tokens decoded so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Share the whole stack's decode caches with a new state
    /// (DESIGN.md §Pages): paged layers fork by page refcount — opening a
    /// session on a cached prompt prefix costs no float copies — while
    /// monolithic layers deep-copy (the sharing-semantics oracle). The
    /// fork is an independent session from here on; continued decoding
    /// diverges the two through copy-on-write.
    pub fn fork(&self) -> Self {
        StackDecodeState {
            layers: self.layers.iter().map(LayerDecodeState::fork).collect(),
            desc: self.desc.clone(),
            len: self.len,
        }
    }

    /// Pages referenced across all layers and heads (0 for monolithic
    /// states; shared pages count once per state).
    pub fn resident_pages(&self) -> usize {
        self.layers.iter().map(LayerDecodeState::resident_pages).sum()
    }

    /// f32 elements across all layers — the measured side of
    /// `memory::stack_decode_state_bytes`, asserted in
    /// `tests/model_props.rs`.
    pub fn f32_elems(&self) -> usize {
        self.layers.iter().map(LayerDecodeState::f32_elems).sum::<usize>()
            + self.desc.iter().map(Vec::len).sum::<usize>()
    }
}

/// Per-step scratch rows for [`SinkhornStack::decode_step`]: the residual
/// stream, pre-norm output, flat head-major q/k/v/context rows, FFN rows,
/// and the streaming-softmax carry. One per sequence driver, reused across
/// steps.
pub struct StackDecodeScratch {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    proj: Vec<f32>,
    ff_pre: Vec<f32>,
    ff_act: Vec<f32>,
    ff_out: Vec<f32>,
    stream: DecodeScratch,
}

impl StackDecodeScratch {
    pub fn new(cfg: &StackConfig) -> Self {
        let d = cfg.d_model;
        StackDecodeScratch {
            x: vec![0.0; d],
            h: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            proj: vec![0.0; d],
            ff_pre: vec![0.0; cfg.d_ff],
            ff_act: vec![0.0; cfg.d_ff],
            ff_out: vec![0.0; if cfg.bare_layers() { 0 } else { d }],
            stream: DecodeScratch::new(),
        }
    }
}

fn check_input(cfg: &StackConfig, x: &Mat) {
    assert_eq!(x.rows, cfg.seq_len, "stack input rows must equal seq_len");
    assert_eq!(x.cols, cfg.d_model, "stack input cols must equal d_model");
}

/// One layer's forward pass over `x` in place (free function so the owned
/// and shared entries can split borrows of `SinkhornStack`).
fn layer_forward(
    cfg: &StackConfig,
    layer: &TransformerLayer,
    strategy: &dyn SortStrategy,
    x: &mut Mat,
    engine: &SinkhornEngine,
    scratch: &mut StackScratch,
) {
    let (nb, heads) = (cfg.nb, cfg.n_heads);
    let b = cfg.block_rows();
    // 1. pre-norm + SortNet + per-head projections, all read-only over the
    // residual stream (or its LayerNorm image)
    let r = {
        let src: &Mat = match &layer.ln1 {
            Some(ln) => {
                layernorm_into(&x.view(), &ln.gamma, &ln.beta, &mut scratch.h.view_mut());
                &scratch.h
            }
            None => &*x,
        };
        // SortNet: mean-pooled block descriptors → (nb, nb) logits (the
        // legacy fallback loop, kept bit-for-bit) → the layer's sort
        // backend turns them into the block-mixing matrix (DESIGN.md
        // §Backends; SinkhornSort is the historical balance, bitwise)
        scratch.blk.data.fill(0.0);
        for i in 0..nb {
            for t in 0..b {
                let xr = src.row(i * b + t);
                for (c, o) in scratch.blk.row_mut(i).iter_mut().enumerate() {
                    *o += xr[c];
                }
            }
        }
        scratch.blk.scale(1.0 / b as f32);
        let logits = scratch.blk.matmul(&layer.sortnet);
        let r = strategy.mix(&logits, cfg.sinkhorn_iters, cfg.causal);
        // per-head projections in the naive oracle's accumulation order
        // (bit-compatible with the legacy `Mat::matmul` path)
        let srcv = src.view();
        for h in 0..heads {
            scratch.qh[h].data.fill(0.0);
            matmul_acc_ordered_into(&srcv, &layer.wq[h].view(), &mut scratch.qh[h].view_mut());
            scratch.kh[h].data.fill(0.0);
            matmul_acc_ordered_into(&srcv, &layer.wk[h].view(), &mut scratch.kh[h].view_mut());
            scratch.vh[h].data.fill(0.0);
            matmul_acc_ordered_into(&srcv, &layer.wv[h].view(), &mut scratch.vh[h].view_mut());
        }
        r
    };
    // 2. multi-head attention: the engine consumes the strategy's gather
    // layout (mixing matrix + window/cut shape) with no knowledge of
    // which backend produced it (DESIGN.md §Backends) — all heads
    // through one pooled entry, no per-layer allocs
    let layout = SortLayout { r: &r, nb, n_cut: cfg.n_cut, causal: cfg.causal };
    engine.layout_attention_into(
        &layout,
        &scratch.qh,
        &scratch.kh,
        &scratch.vh,
        &mut scratch.ctx,
        &mut scratch.ws,
    );
    // 3. per-head output projection summed into the residual stream
    scratch.proj.data.fill(0.0);
    for h in 0..heads {
        let ctxv = scratch.ctx[h].view();
        matmul_acc_ordered_into(&ctxv, &layer.wo[h].view(), &mut scratch.proj.view_mut());
    }
    x.add(&scratch.proj);
    // 4. pre-norm GELU FFN on the tiled kernels (fused bias + matmul)
    if let Some(ffn) = &layer.ffn {
        layernorm_into(&x.view(), &ffn.ln.gamma, &ffn.ln.beta, &mut scratch.h.view_mut());
        bias_rows_into(&ffn.b1, &mut scratch.ff_pre.view_mut());
        matmul_acc_into(&scratch.h.view(), &ffn.w1.view(), &mut scratch.ff_pre.view_mut());
        gelu_into(&scratch.ff_pre.view(), &mut scratch.ff_act.view_mut());
        bias_rows_into(&ffn.b2, &mut scratch.ff_out.view_mut());
        matmul_acc_into(&scratch.ff_act.view(), &ffn.w2.view(), &mut scratch.ff_out.view_mut());
        x.add(&scratch.ff_out);
    }
}

#[cfg(test)]
mod tests {
    // The heavy property suites (stack vs the naive per-layer oracle,
    // depth-1 bitwise legacy equivalence, incremental decode vs the
    // full-prefix oracle, thread invariance, memory accounting) live in
    // tests/model_props.rs — only construction edge cases are covered
    // here.
    use super::*;

    fn cfg(depth: usize, heads: usize, d_ff: usize) -> StackConfig {
        StackConfig {
            seq_len: 12,
            d_model: 8,
            n_heads: heads,
            depth,
            d_ff,
            nb: 3,
            sinkhorn_iters: 4,
            causal: false,
            n_cut: None,
        }
    }

    #[test]
    fn seeded_stack_is_deterministic() {
        let a = SinkhornStack::seeded(cfg(2, 2, 16), 7, SinkhornEngine::serial()).unwrap();
        let b = SinkhornStack::seeded(cfg(2, 2, 16), 7, SinkhornEngine::serial()).unwrap();
        assert_eq!(a.n_params(), b.n_params());
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.sortnet, lb.sortnet);
            assert_eq!(la.wq[0], lb.wq[0]);
            assert_eq!(la.ffn.as_ref().unwrap().w1, lb.ffn.as_ref().unwrap().w1);
        }
        // different layers get different weights
        assert_ne!(a.layers[0].wq[0], a.layers[1].wq[0]);
        assert_ne!(a.layers[0].wq[0], a.layers[0].wk[0]);
        assert_ne!(a.layers[0].wq[0], a.layers[0].wq[1]);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(StackConfig { n_heads: 3, ..cfg(1, 1, 0) }.validate().is_err()); // 3 ∤ 8
        assert!(StackConfig { depth: 0, ..cfg(1, 1, 0) }.validate().is_err());
        assert!(StackConfig { nb: 5, ..cfg(1, 1, 0) }.validate().is_err()); // 5 ∤ 12
        assert!(StackConfig { n_cut: Some(4), ..cfg(1, 1, 0) }.validate().is_err()); // > nb
        assert!(StackConfig { n_cut: Some(2), causal: true, ..cfg(1, 1, 0) }
            .validate()
            .is_err());
        assert!(cfg(2, 2, 16).validate().is_ok());
    }

    #[test]
    fn stack_rejects_mismatched_layers() {
        let c1 = cfg(1, 1, 0);
        let c2 = cfg(2, 2, 16);
        let mut rng = Rng::new(3);
        let bare = TransformerLayer::seeded(&c1, &mut rng);
        // depth mismatch
        let eng = SinkhornEngine::serial();
        assert!(SinkhornStack::new(c2.clone(), vec![bare.clone()], eng).is_err());
        // shape mismatch (bare layer against a full config)
        assert!(SinkhornStack::new(
            c2,
            vec![bare.clone(), bare],
            SinkhornEngine::serial()
        )
        .is_err());
    }

    #[test]
    #[should_panic(expected = "seq_len")]
    fn forward_rejects_wrong_length() {
        let mut stack = SinkhornStack::seeded(cfg(1, 1, 0), 5, SinkhornEngine::serial()).unwrap();
        let mut x = Mat::zeros(8, 8);
        stack.forward(&mut x);
    }

    #[test]
    #[should_panic(expected = "decode capacity exhausted")]
    fn decode_overflow_panics() {
        let stack = SinkhornStack::seeded(cfg(1, 1, 0), 5, SinkhornEngine::serial()).unwrap();
        let mut st = stack.decode_state();
        let mut scratch = stack.new_decode_scratch();
        let row = vec![0.1f32; 8];
        let mut out = vec![0.0f32; 8];
        for _ in 0..13 {
            stack.decode_step(&mut st, &row, &mut scratch, &mut out);
        }
    }
}
