"""L1 kernel microbenchmarks: slab vs tile grid layouts, fwd and fwd+bwd,
plus the analytic TPU estimates (VMEM footprint, MXU-shaped MAC fraction,
FLOP ratio vs dense attention) recorded in EXPERIMENTS.md §Perf.

Usage: cd python && python -m compile.bench_kernels [--iters 10]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .kernels import attention_kernel as ak
from .kernels import ref


def timeit(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def flop_ratio(ell: int, nb: int, d: int) -> float:
    """Sinkhorn attention MACs / dense attention MACs (per head)."""
    b = ell // nb
    sink = 2 * ell * (2 * b) * d + 2 * nb * nb * b * d
    dense = 2 * ell * ell * d
    return sink / dense


def vmem_kib(b: int, d: int) -> float:
    return (5 * b * d + 2 * b * b) * 4 / 1024


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()

    print("== structure (TPU estimates) ==")
    for ell, nb in [(1024, 16), (2048, 32), (4096, 32)]:
        b = ell // nb
        print(
            f"  ell={ell:5} nb={nb:3} b={b:4}: FLOPs {flop_ratio(ell, nb, 64)*100:5.1f}% of dense, "
            f"VMEM/tile {vmem_kib(b, 64):8.1f} KiB"
        )

    print(f"\n== interpret-mode wallclock (CPU, iters={args.iters}) ==")
    key = jax.random.PRNGKey(0)
    for (g, nb, b, d) in [(32, 8, 16, 16), (32, 4, 32, 16), (8, 8, 32, 32)]:
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (g, nb, b, d))
        k = jax.random.normal(ks[1], (g, nb, b, d))
        v = jax.random.normal(ks[2], (g, nb, b, d))
        s = jax.vmap(lambda x: ref.sinkhorn_log(x, 5))(jax.random.normal(ks[3], (g, nb, nb)))
        ksort = jnp.einsum("gij,gjbd->gibd", s, k)
        vsort = jnp.einsum("gij,gjbd->gibd", s, v)
        valid = jnp.ones((g, nb))
        for mode in ("slab", "tile"):
            fwd = jax.jit(
                lambda q, k, v, ks_, vs_: ak.sinkhorn_block_attention(
                    q, k, v, ks_, vs_, valid, mode=mode
                )
            )
            t_f = timeit(fwd, q, k, v, ksort, vsort, iters=args.iters)
            grad = jax.jit(
                jax.grad(
                    lambda q, k, v, ks_, vs_: ak.sinkhorn_block_attention(
                        q, k, v, ks_, vs_, valid, mode=mode
                    ).sum(),
                    argnums=(0, 1, 2),
                )
            )
            t_b = timeit(grad, q, k, v, ksort, vsort, iters=args.iters)
            print(
                f"  G={g:3} nb={nb:2} b={b:3} d={d:3} [{mode:4}]  "
                f"fwd {t_f:8.2f} ms   fwd+bwd {t_b:8.2f} ms"
            )


if __name__ == "__main__":
    main()
