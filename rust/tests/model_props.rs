//! Property tests for the multi-layer Sinkhorn Transformer stack against
//! its naive per-layer oracles — run with no artifacts and no XLA, in
//! every build. The contract under test (DESIGN.md §Model):
//!
//! 1. a depth-1 *bare* stack (one head, no LayerNorm, no FFN) reproduces
//!    the historical single-layer fallback math **bitwise** — naive-order
//!    projections, engine attention, `ctx @ wo`, residual;
//! 2. the full engine stack (pre-LN, multi-head, GELU FFN, depth L)
//!    matches the naive per-layer oracle
//!    `attention::reference_stack_forward` within 1e-5 max-abs across
//!    tile-tail shapes, multi-tile blocks and SortCut widths;
//! 3. the incremental depth-L decode (`SinkhornStack::decode_step`)
//!    matches the full-prefix per-layer oracle
//!    `attention::reference_stack_decode` at every step, including steps
//!    that cross block boundaries and partial final blocks;
//! 4. the stack is bit-identical across engine thread counts, and the
//!    batched forward is bit-identical to the single forward;
//! 5. parameters, forward scratch and decode state match the analytic
//!    `memory` models exactly.

use sinkhorn::sinkhorn::engine::{ENGINE_TOL as TOL, STREAM_TILE_W};
use sinkhorn::sinkhorn::memory::{stack_decode_state_bytes, stack_params, stack_scratch_elems};
use sinkhorn::sinkhorn::model::StackScratch;
use sinkhorn::sinkhorn::{
    reference_stack_decode, reference_stack_forward, sinkhorn_attention, Mat, SinkhornEngine,
    SinkhornStack, StackConfig, WorkerPool,
};
use sinkhorn::util::prop::{forall, Gen};
use sinkhorn::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * 0.5)
}

fn cfg(
    nb: usize,
    b: usize,
    d_model: usize,
    n_heads: usize,
    depth: usize,
    d_ff: usize,
) -> StackConfig {
    StackConfig {
        seq_len: nb * b,
        d_model,
        n_heads,
        depth,
        d_ff,
        nb,
        sinkhorn_iters: 5,
        causal: false,
        n_cut: None,
    }
}

struct Case {
    cfg: StackConfig,
    x: Mat,
    seed: u64,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let c = &self.cfg;
        write!(
            f,
            "Case(nb={}, b={}, d={}, heads={}, depth={}, d_ff={}, cut={:?})",
            c.nb,
            c.block_rows(),
            c.d_model,
            c.n_heads,
            c.depth,
            c.d_ff,
            c.n_cut
        )
    }
}

fn gen_case(g: &mut Gen) -> Case {
    // heads * head-dim straddles the microkernel tile widths; half the
    // cases get an FFN, a third get SortCut
    let nb = 2 + g.usize(0, 3);
    let b = 2 + g.usize(0, 4);
    let n_heads = 1 + g.usize(0, 2);
    let d_head = 2 + g.usize(0, 5);
    let d_model = n_heads * d_head;
    let depth = 1 + g.usize(0, 2);
    let d_ff = if g.usize(0, 2) == 0 { 0 } else { d_model * 2 + 1 };
    let mut c = cfg(nb, b, d_model, n_heads, depth, d_ff);
    if g.usize(0, 3) == 0 {
        c.n_cut = Some(1 + g.usize(0, nb - 1));
    }
    let mut rng = Rng::new(g.rng.next_u64());
    let x = rand_mat(&mut rng, c.seq_len, c.d_model);
    Case { cfg: c, x, seed: rng.next_u64() }
}

fn forward(case: &Case, threads: usize) -> Mat {
    let mut stack =
        SinkhornStack::seeded(case.cfg.clone(), case.seed, SinkhornEngine::new(threads)).unwrap();
    let mut x = case.x.clone();
    stack.forward(&mut x);
    x
}

#[test]
fn stack_matches_per_layer_oracle_across_shapes() {
    forall(24, 0x40DE, gen_case, |c| {
        let stack = SinkhornStack::seeded(c.cfg.clone(), c.seed, SinkhornEngine::serial()).unwrap();
        let want = reference_stack_forward(&c.x, &stack.cfg, &stack.layers);
        let got = forward(c, 1);
        let diff = got.max_abs_diff(&want);
        if diff > TOL {
            return Err(format!("stack vs per-layer oracle max-abs {diff}"));
        }
        Ok(())
    });
}

#[test]
fn stack_handles_multi_tile_blocks_and_tile_tails() {
    // fixed shapes targeting the seams: b > STREAM_TILE_W (one block spans
    // several streaming key tiles), head dims off the 4/8-wide kernel
    // tiles, depth with and without FFN
    let shapes = [
        (2usize, STREAM_TILE_W + 3, 2usize, 7usize, 2usize, 0usize),
        (3, STREAM_TILE_W + 1, 1, 9, 1, 19),
        (2, 5, 3, 3, 3, 13),
        (4, 3, 2, 2, 2, 0),
    ];
    let mut rng = Rng::new(0x40DF);
    for (nb, b, heads, d_head, depth, d_ff) in shapes {
        let c = cfg(nb, b, heads * d_head, heads, depth, d_ff);
        let x = rand_mat(&mut rng, c.seq_len, c.d_model);
        let case = Case { cfg: c, x, seed: rng.next_u64() };
        let stack =
            SinkhornStack::seeded(case.cfg.clone(), case.seed, SinkhornEngine::serial()).unwrap();
        let want = reference_stack_forward(&case.x, &stack.cfg, &stack.layers);
        let got = forward(&case, 1);
        let diff = got.max_abs_diff(&want);
        assert!(
            diff <= TOL,
            "shape (nb={nb}, b={b}, heads={heads}, d_head={d_head}, depth={depth}, \
             d_ff={d_ff}): max-abs {diff}"
        );
    }
}

#[test]
fn stack_sortcut_matches_oracle_for_every_cut() {
    let mut rng = Rng::new(0x40E0);
    let base = cfg(4, 3, 8, 2, 2, 17);
    let x = rand_mat(&mut rng, base.seq_len, base.d_model);
    for cut in 1..=base.nb {
        let mut c = base.clone();
        c.n_cut = Some(cut);
        let case = Case { cfg: c, x: x.clone(), seed: 0xC07 + cut as u64 };
        let stack =
            SinkhornStack::seeded(case.cfg.clone(), case.seed, SinkhornEngine::serial()).unwrap();
        let want = reference_stack_forward(&case.x, &stack.cfg, &stack.layers);
        let got = forward(&case, 1);
        let diff = got.max_abs_diff(&want);
        assert!(diff <= TOL, "cut={cut}: max-abs {diff}");
    }
}

#[test]
fn stack_is_thread_invariant_bitwise_and_batch_equals_single() {
    forall(10, 0x40E1, gen_case, |c| {
        let serial = forward(c, 1);
        for threads in [2usize, 5] {
            let got = forward(c, threads);
            if got != serial {
                return Err(format!(
                    "threads={threads}: stack not thread-invariant (max diff {})",
                    got.max_abs_diff(&serial)
                ));
            }
        }
        // batched forward: same bits for every request
        let stack =
            SinkhornStack::seeded(c.cfg.clone(), c.seed, SinkhornEngine::new(3)).unwrap();
        let mut xs: Vec<Mat> = (0..3).map(|_| c.x.clone()).collect();
        stack.forward_batch(&mut xs, &WorkerPool::new(2));
        for (i, xb) in xs.iter().enumerate() {
            if xb != &serial {
                return Err(format!("batch seq {i} diverged from the single forward"));
            }
        }
        Ok(())
    });
}

/// The depth-1 bare stack must be bit-identical to the historical
/// single-layer fallback math, reconstructed operation by operation from
/// the same weights: q/k/v via `Mat::matmul`, SortNet over mean-pooled
/// block descriptors, one engine attention pass, `ctx @ wo`, residual.
#[test]
fn bare_depth1_stack_is_bitwise_legacy_single_layer() {
    let mut rng = Rng::new(0x40E2);
    for (nb, b, d) in [(4usize, 8usize, 16usize), (2, 5, 7), (3, 4, 12)] {
        let c = cfg(nb, b, d, 1, 1, 0);
        let x = rand_mat(&mut rng, c.seq_len, d);
        let mut stack =
            SinkhornStack::seeded(c.clone(), 0xB17 ^ d as u64, SinkhornEngine::serial()).unwrap();
        let layer = stack.layers[0].clone();
        // legacy math
        let q = x.matmul(&layer.wq[0]);
        let k = x.matmul(&layer.wk[0]);
        let v = x.matmul(&layer.wv[0]);
        let mut blk = Mat::zeros(nb, d);
        for i in 0..nb {
            for t in 0..b {
                let xr = x.row(i * b + t);
                for (cc, o) in blk.row_mut(i).iter_mut().enumerate() {
                    *o += xr[cc];
                }
            }
        }
        blk.scale(1.0 / b as f32);
        let r = sinkhorn::sinkhorn::balance::sinkhorn(
            &blk.matmul(&layer.sortnet),
            c.sinkhorn_iters,
        );
        let eng = SinkhornEngine::serial();
        let ctx = eng.attention(&q, &k, &v, &r, nb, false);
        let mut want = x.clone();
        want.add(&ctx.matmul(&layer.wo[0]));
        // the oracle-equivalence sanity check: legacy math is also the
        // naive attention path up to epsilon
        let naive = sinkhorn_attention(&q, &k, &v, &r, nb, false);
        assert!(ctx.max_abs_diff(&naive) <= TOL);
        // stack forward, bit for bit
        let mut got = x.clone();
        stack.forward(&mut got);
        assert_eq!(got, want, "bare depth-1 stack drifted from the legacy math (nb={nb})");
    }
}

#[test]
fn incremental_stack_decode_matches_full_prefix_oracle() {
    // every step, block boundaries, partial final blocks, with and
    // without FFN/heads/SortCut
    let mut rng = Rng::new(0x40E3);
    let shapes: [(usize, usize, usize, usize, usize, usize, Option<usize>); 4] = [
        (3, 4, 1, 6, 1, 0, None),       // bare single layer (legacy shape)
        (3, 3, 2, 4, 2, 11, None),      // full layers, 2 heads, depth 2
        (2, 5, 1, 9, 3, 7, Some(1)),    // SortCut decode, depth 3
        (4, 2, 2, 3, 2, 0, Some(2)),    // bare multi-head SortCut
    ];
    for (nb, b, heads, d_head, depth, d_ff, cut) in shapes {
        let mut c = cfg(nb, b, heads * d_head, heads, depth, d_ff);
        c.n_cut = cut;
        let total = nb * b - b / 2; // end mid-block
        let stack =
            SinkhornStack::seeded(c.clone(), 0xDE60 ^ depth as u64, SinkhornEngine::serial())
                .unwrap();
        let x = rand_mat(&mut rng, total, c.d_model);
        let want = reference_stack_decode(&x, &stack.cfg, &stack.layers);
        let mut st = stack.decode_state();
        let mut scratch = stack.new_decode_scratch();
        let mut out = vec![0.0f32; c.d_model];
        for t in 0..total {
            stack.decode_step(&mut st, x.row(t), &mut scratch, &mut out);
            for (e, &got) in out.iter().enumerate() {
                let dv = (got - want[(t, e)]).abs();
                assert!(
                    dv <= TOL,
                    "shape (nb={nb}, b={b}, heads={heads}, depth={depth}, d_ff={d_ff}, \
                     cut={cut:?}) step {t} col {e}: diverged by {dv}"
                );
            }
        }
        assert_eq!(st.len(), total);
    }
}

#[test]
fn decode_is_deterministic_across_scratch_reuse() {
    // one scratch driving two sequences back to back must reproduce a
    // fresh-scratch run bit for bit (the per-worker reuse contract)
    let c = cfg(3, 4, 8, 2, 2, 16);
    let stack = SinkhornStack::seeded(c.clone(), 99, SinkhornEngine::serial()).unwrap();
    let mut rng = Rng::new(0x40E4);
    let x = rand_mat(&mut rng, c.seq_len, c.d_model);
    let run = |scratch: &mut sinkhorn::sinkhorn::StackDecodeScratch| -> Vec<Vec<f32>> {
        let mut st = stack.decode_state();
        let mut out = vec![0.0f32; c.d_model];
        (0..c.seq_len)
            .map(|t| {
                stack.decode_step(&mut st, x.row(t), scratch, &mut out);
                out.clone()
            })
            .collect()
    };
    let mut scratch = stack.new_decode_scratch();
    let first = run(&mut scratch);
    let reused = run(&mut scratch); // same scratch, fresh state
    assert_eq!(first, reused);
}

#[test]
fn params_scratch_and_decode_state_match_memory_models() {
    for (nb, b, heads, d_head, depth, d_ff, cut) in [
        (4usize, 8usize, 1usize, 16usize, 1usize, 0usize, None),
        (4, 8, 2, 8, 2, 32, None),
        (2, 16, 4, 4, 3, 64, Some(2)),
    ] {
        let mut c = cfg(nb, b, heads * d_head, heads, depth, d_ff);
        c.n_cut = cut;
        let stack = SinkhornStack::seeded(c.clone(), 5, SinkhornEngine::new(3)).unwrap();
        assert_eq!(
            stack.n_params(),
            stack_params(&c),
            "param accounting drifted at depth={depth}"
        );
        for threads in [1usize, 3] {
            assert_eq!(
                StackScratch::new(&c, threads).f32_elems(),
                stack_scratch_elems(&c, threads),
                "scratch accounting drifted (threads={threads})"
            );
        }
        let st = stack.decode_state();
        assert_eq!(
            st.f32_elems() * 4,
            stack_decode_state_bytes(depth, heads, b, d_head, nb, cut),
            "decode-state accounting drifted at depth={depth}"
        );
        assert!(st.is_empty());
        assert_eq!(st.depth(), depth);
    }
}
