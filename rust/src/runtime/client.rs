//! PJRT runtime: loads HLO-text artifacts, compiles them once, executes
//! them from the coordinator hot path. Python is never involved here.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

/// Wraps the PJRT CPU client with a compile cache keyed by artifact path.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative (compile_secs, n_compiles) for the perf report.
    pub compile_stats: RefCell<(f64, usize)>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            compile_stats: RefCell::new((0.0, 0)),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serializes protos
    /// with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see DESIGN.md §2).
    pub fn load(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(path) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        {
            let mut st = self.compile_stats.borrow_mut();
            st.0 += t0.elapsed().as_secs_f64();
            st.1 += 1;
        }
        self.cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute a compiled graph on literals; returns the flattened output
    /// tuple (all our graphs are lowered with `return_tuple=True`).
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let buffers = exe.execute::<&xla::Literal>(args).context("executing graph")?;
        let out = buffers[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// Number of graphs compiled so far (test/diagnostic hook).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
