//! Metric conversions + loss-curve recording (the paper reports ppl, bpc,
//! bpd, accuracy, EM and edit distance depending on the task).

/// Word-level perplexity from mean token xent (nats).
pub fn perplexity(loss_nats: f64) -> f64 {
    loss_nats.exp()
}

/// Bits-per-character from mean char xent (nats).
pub fn bpc(loss_nats: f64) -> f64 {
    loss_nats / std::f64::consts::LN_2
}

/// Bits-per-dimension for pixel modeling — same conversion, per subpixel.
pub fn bpd(loss_nats: f64) -> f64 {
    bpc(loss_nats)
}

/// A recorded training run: (step, loss) samples + wall-clock.
#[derive(Debug, Clone, Default)]
pub struct LossCurve {
    pub points: Vec<(usize, f64)>,
    pub secs: f64,
}

impl LossCurve {
    pub fn push(&mut self, step: usize, loss: f64) {
        self.points.push((step, loss));
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.points.last().map(|&(_, l)| l)
    }

    /// Mean loss over the last `k` recorded points (smoothed endpoint).
    pub fn tail_mean(&self, k: usize) -> Option<f64> {
        if self.points.is_empty() {
            return None;
        }
        let n = self.points.len().min(k.max(1));
        Some(self.points[self.points.len() - n..].iter().map(|&(_, l)| l).sum::<f64>() / n as f64)
    }

    /// True if the curve went down overall (sanity check for examples).
    pub fn decreased(&self) -> bool {
        match (self.points.first(), self.tail_mean(5)) {
            (Some(&(_, first)), Some(tail)) => tail < first,
            _ => false,
        }
    }

    /// Render a compact ASCII sparkline of the loss curve.
    pub fn sparkline(&self, width: usize) -> String {
        if self.points.is_empty() {
            return String::new();
        }
        let glyphs = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let lo = self.points.iter().map(|&(_, l)| l).fold(f64::INFINITY, f64::min);
        let hi = self.points.iter().map(|&(_, l)| l).fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-9);
        let n = self.points.len();
        (0..width.min(n))
            .map(|i| {
                let idx = i * n / width.min(n);
                let v = (self.points[idx].1 - lo) / span;
                glyphs[((v * 7.0).round() as usize).min(7)]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert!((perplexity(0.0) - 1.0).abs() < 1e-12);
        assert!((bpc(std::f64::consts::LN_2) - 1.0).abs() < 1e-12);
        assert!((perplexity(3.7) - 40.447).abs() < 0.01);
    }

    #[test]
    fn curve_tail_and_decrease() {
        let mut c = LossCurve::default();
        for (s, l) in [(0, 5.0), (10, 4.0), (20, 3.0), (30, 2.0)] {
            c.push(s, l);
        }
        assert!(c.decreased());
        assert_eq!(c.final_loss(), Some(2.0));
        assert!((c.tail_mean(2).unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn sparkline_monotone() {
        let mut c = LossCurve::default();
        for i in 0..16 {
            c.push(i, 16.0 - i as f64);
        }
        let s = c.sparkline(8);
        assert_eq!(s.chars().count(), 8);
        assert!(s.starts_with('█') && s.ends_with('▁'));
    }
}
